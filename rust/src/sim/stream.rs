//! Row/event-granular simulation of the generated streaming pipeline.
//!
//! Execution model (one frame):
//! * The source streams the padded frame row by row; each row costs
//!   `W + Pb + Pf` cycles plus a 2-cycle valid/ready handshake bubble.
//! * A serialized stage (serial_factor > 1) consumes one full source
//!   replay per pass; between passes it drains its MAC pipeline and
//!   reloads the next filter set's weights (`K^2` cycles per lane).
//! * Clock-gated stages are skipped entirely: no cycles, no dynamic
//!   power, exactly like a gated BUFGCE region. Re-activation costs one
//!   full-frame delay (Sec. V: "resume ... after a full-frame delay").
//! * Power integrates per-stage activity over busy cycles.
//!
//! The walk order is the [`StagePlan`]'s topological stage order — for
//! chains identical to the old layer-list walk, for branchy graphs the
//! only order in which every producer is simulated before its consumer.
//! `Upsample` stages replay at their *output* frame geometry (they emit
//! more rows than they consume); everything else replays its local input.

use crate::design::{self, DesignConfig, DesignEval};
use crate::graph::passes::{self, StagePlan};
use crate::graph::{LayerKind, Network};
use crate::pe::{Blanking, Device};
use crate::power::{Activity, PowerModel};

/// Runtime clock-gating state for NeuroMorph morphing.
#[derive(Debug, Clone)]
pub struct GateMask {
    /// per-conv-block enable (depth-wise morphing); empty = all active.
    /// Block `i` is the StagePlan's gate block `i` (the i-th conv-like
    /// stage in stream order).
    pub block_active: Vec<bool>,
    /// fraction of filter lanes active per block (width-wise morphing)
    pub width_fraction: f64,
}

/// Rejected width fraction (the morph/governor boundary refuses to run a
/// design at an out-of-range width instead of silently clamping).
#[derive(Debug, Clone, PartialEq)]
pub struct GateError {
    pub fraction: f64,
}

impl std::fmt::Display for GateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "width fraction {} outside the deployable range [0.1, 1.0]",
            self.fraction
        )
    }
}

impl std::error::Error for GateError {}

impl GateMask {
    pub fn all_active() -> GateMask {
        GateMask { block_active: Vec::new(), width_fraction: 1.0 }
    }

    /// Depth-wise morph: keep the first `depth` conv blocks running.
    /// (Gate bits follow the StagePlan's gate-block numbering, which for
    /// every network equals the conv-like stage count.)
    pub fn depth_prefix(net: &Network, depth: usize) -> GateMask {
        let n = net.conv_layer_ids().len();
        GateMask {
            block_active: (0..n).map(|i| i < depth).collect(),
            width_fraction: 1.0,
        }
    }

    /// Width-wise morph: all blocks active at `fraction` of their lanes.
    /// Silently clamps to the deployable range — CLI/simulator
    /// convenience; validated boundaries use [`GateMask::try_width`].
    pub fn width(fraction: f64) -> GateMask {
        GateMask { block_active: Vec::new(), width_fraction: fraction.clamp(0.1, 1.0) }
    }

    /// Width-wise morph with explicit validation: a fraction outside
    /// `[0.1, 1.0]` (or NaN) is an error, so a corrupt manifest cannot
    /// quietly run the fabric at the clamp floor.
    pub fn try_width(fraction: f64) -> Result<GateMask, GateError> {
        if (0.1..=1.0).contains(&fraction) {
            Ok(GateMask { block_active: Vec::new(), width_fraction: fraction })
        } else {
            Err(GateError { fraction })
        }
    }

    fn is_active(&self, block: usize) -> bool {
        self.block_active.get(block).copied().unwrap_or(true)
    }
}

/// Per-stage simulation statistics.
#[derive(Debug, Clone)]
pub struct StageStats {
    pub name: String,
    pub busy_cycles: u64,
    pub passes: u64,
    pub stall_cycles: u64,
    pub gated: bool,
}

/// Whole-frame simulation report.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub latency_cycles: u64,
    pub period_cycles: u64,
    pub per_stage: Vec<StageStats>,
    pub power_mw: f64,
    pub clock_mhz: f64,
    /// elaborated resource footprint: the analytical allocation plus the
    /// control/routing logic a real netlist carries (per-stage FSMs,
    /// stream handshake, inter-stage crossbar). This is the "Real" column
    /// of Table III — DSP/BRAM match the estimate exactly (they are
    /// explicitly instantiated), LUTs grow a few percent.
    pub resources: crate::pe::Resources,
}

impl SimReport {
    pub fn latency_ms(&self) -> f64 {
        self.latency_cycles as f64 / (self.clock_mhz * 1e3)
    }

    pub fn fps(&self) -> f64 {
        self.clock_mhz * 1e6 / self.period_cycles as f64
    }

    pub fn energy_per_frame_j(&self) -> f64 {
        self.power_mw / 1000.0 * (self.period_cycles as f64 / (self.clock_mhz * 1e6))
    }
}

/// Handshake bubble per streamed row (valid/ready resynchronization).
const ROW_BUBBLE: u64 = 2;
/// Extra drain cycles when a stage switches to its next sequential pass.
const PASS_DRAIN: u64 = 6;

/// Simulate one frame through the configured design under a gate mask.
///
/// Convenience wrapper that schedules the pass pipeline and evaluates the
/// design point on every call; hot paths that replay many frames on one
/// fixed design (the serving backends) should pre-compute both once and
/// call [`simulate_with`].
pub fn simulate(
    net: &Network,
    cfg: &DesignConfig,
    device: &Device,
    gate: &GateMask,
) -> SimReport {
    let plan = passes::schedule(net).expect("validated network");
    let eval = design::evaluate_plan(&plan, cfg, device).expect("valid design point");
    simulate_with(&plan, device, gate, &eval)
}

/// Simulate one frame against a pre-scheduled plan and pre-evaluated
/// design point. This is the per-frame hot path of the cycle-level
/// serving backend: pass scheduling and the analytical evaluation (both
/// allocation-heavy) are hoisted out of the frame loop by the caller.
pub fn simulate_with(
    plan: &StagePlan,
    device: &Device,
    gate: &GateMask,
    eval: &DesignEval,
) -> SimReport {
    let blank = Blanking::default();

    let mut per_stage = Vec::new();
    let mut gated_from_here = false; // depth gating truncates the pipeline
    let (in_h, in_w, _) = plan.input_dims;
    // the source itself paces at the input frame rate
    let mut bottleneck: u64 = in_h as u64
        * ((in_w + blank.back_porch + blank.front_porch) as u64 + ROW_BUBBLE);
    let mut fill_total: u64 = 0;
    let mut serialized_total: u64 = 0;
    // power accumulators
    let pm = PowerModel::default();
    let mut active_dsp = 0usize;
    let mut active_lut = 0usize;
    let mut active_bram = 0usize;

    for stage in &plan.stages {
        let m = &eval.mappings[stage.id];
        let is_conv = stage.is_conv_like();
        if let Some(b) = stage.gate_block {
            if !gate.is_active(b) {
                gated_from_here = true;
            }
        }
        let gated = gated_from_here;

        if gated {
            per_stage.push(StageStats {
                name: m.name.clone(),
                busy_cycles: 0,
                passes: 0,
                stall_cycles: 0,
                gated: true,
            });
            continue;
        }

        // width morphing scales the pass count of conv stages: half the
        // lanes active -> the *work* also halves (half the filters run),
        // so serial passes stay, but each pass covers fewer filters; net
        // effect matches width-gated subnet = fewer total passes.
        let serial = if is_conv && gate.width_fraction < 1.0 {
            ((m.serial_factor as f64) * gate.width_fraction).ceil().max(1.0) as u64
        } else {
            m.serial_factor as u64
        };

        let weight_reload = match stage.kind {
            LayerKind::Conv { k, .. } | LayerKind::DwConv { k, .. } => (k * k) as u64,
            _ => 0,
        };
        // one pass replays the stage's LOCAL fmap from its buffers:
        // H rows of (W + porches) px + a per-row handshake bubble.
        // Upsample emits its larger OUTPUT frame, so it replays at the
        // output geometry.
        let rep_shape = match stage.kind {
            LayerKind::Upsample { .. } => stage.output,
            _ => stage.input,
        };
        let replay_cycles = rep_shape.h as u64
            * ((rep_shape.w + blank.back_porch + blank.front_porch) as u64 + ROW_BUBBLE);
        let busy = serial * replay_cycles.max(1)
            + serial.saturating_sub(1) * (PASS_DRAIN + weight_reload);
        let stall = serial * rep_shape.h as u64 * ROW_BUBBLE;
        bottleneck = bottleneck.max(busy);
        fill_total += m.fill_cycles as u64;
        if serial > 1 {
            // a serialized stage buffers its whole input before pass 2:
            // it contributes its full busy time to the critical path
            serialized_total += busy;
        }

        // resources active on this stage (width gating scales lanes)
        let lane_scale = if is_conv { gate.width_fraction } else { 1.0 };
        active_dsp += (m.resources.dsp as f64 * lane_scale) as usize;
        active_lut += (m.resources.lut as f64 * lane_scale) as usize;
        active_bram += m.resources.bram;

        per_stage.push(StageStats {
            name: m.name.clone(),
            busy_cycles: busy,
            passes: serial,
            stall_cycles: stall,
            gated: false,
        });
    }

    // Eq. 12-13 with simulated overheads: source stream + fills +
    // serialized-stage accumulation (mirrors design::evaluate's model,
    // plus the handshake/drain costs only the simulator sees).
    let source = in_h as u64
        * ((in_w + blank.back_porch + blank.front_porch) as u64 + ROW_BUBBLE);
    let latency = source + fill_total + serialized_total;
    let active_res = crate::pe::Resources {
        dsp: active_dsp,
        lut: active_lut,
        ff: 0,
        bram: active_bram,
    };
    // allocated-but-gated logic leaks only; active logic toggles.
    let power = pm.total_mw(&active_res, device.clock_mhz, Activity::default());

    // Elaborated netlist footprint: the estimator's allocation plus
    // control logic it deliberately omits (Alg. 1 only looks up Table I):
    // a stream-handshake FSM per stage and routing fabric that grows
    // slowly with the PE population. DSP/BRAM are explicit instances —
    // identical to the estimate (the paper's 0% error columns).
    let stages = per_stage.len();
    let elaborated = crate::pe::Resources {
        dsp: eval.resources.dsp,
        lut: eval.resources.lut + 140 * stages + eval.resources.lut / 25,
        ff: eval.resources.ff + 90 * stages,
        bram: eval.resources.bram,
    };

    SimReport {
        latency_cycles: latency,
        period_cycles: bottleneck,
        per_stage,
        power_mw: power,
        clock_mhz: device.clock_mhz,
        resources: elaborated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::DesignConfig;
    use crate::graph::zoo;
    use crate::pe::{FpRep, ZYNQ_7100};

    fn mnist_sim(p: usize, gate: &GateMask) -> SimReport {
        let net = zoo::mnist();
        let cfg = DesignConfig::uniform(&net, p, FpRep::Int16);
        simulate(&net, &cfg, &ZYNQ_7100, gate)
    }

    #[test]
    fn serialized_designs_slower() {
        let fast = mnist_sim(8, &GateMask::all_active());
        let slow = mnist_sim(1, &GateMask::all_active());
        assert!(slow.latency_cycles > 10 * fast.latency_cycles);
    }

    #[test]
    fn pass_counts_match_serialization() {
        let r = mnist_sim(1, &GateMask::all_active());
        let conv_passes: Vec<u64> = r
            .per_stage
            .iter()
            .filter(|s| s.name.starts_with("conv"))
            .map(|s| s.passes)
            .collect();
        assert_eq!(conv_passes, vec![8, 128, 512]);
    }

    #[test]
    fn depth_gating_truncates_pipeline() {
        let r = mnist_sim(4, &GateMask::depth_prefix(&zoo::mnist(), 1));
        // stages after the first conv block are gated
        let gated: Vec<&str> = r
            .per_stage
            .iter()
            .filter(|s| s.gated)
            .map(|s| s.name.as_str())
            .collect();
        assert!(gated.iter().any(|n| n.starts_with("conv") && *n != "conv1"));
        // and the bottleneck shrinks vs full
        let full = mnist_sim(4, &GateMask::all_active());
        assert!(r.latency_cycles < full.latency_cycles);
    }

    #[test]
    fn width_gating_halves_work() {
        let full = mnist_sim(2, &GateMask::all_active());
        let half = mnist_sim(2, &GateMask::width(0.5));
        let ratio = half.period_cycles as f64 / full.period_cycles as f64;
        assert!((0.4..0.75).contains(&ratio), "ratio {ratio}");
        assert!(half.power_mw < full.power_mw);
    }

    #[test]
    fn fps_and_energy_consistent() {
        let r = mnist_sim(4, &GateMask::all_active());
        let fps = r.fps();
        let e = r.energy_per_frame_j();
        assert!(fps > 0.0 && e > 0.0);
        // P = E * fps (steady state)
        assert!((e * fps * 1000.0 - r.power_mw).abs() / r.power_mw < 1e-9);
    }

    #[test]
    fn gate_mask_defaults() {
        let g = GateMask::all_active();
        assert!(g.is_active(0) && g.is_active(99));
        let d = GateMask::depth_prefix(&zoo::mnist(), 2);
        assert!(d.is_active(0) && d.is_active(1) && !d.is_active(2));
    }

    #[test]
    fn width_fraction_clamped() {
        let g = GateMask::width(0.0);
        assert!(g.width_fraction >= 0.1);
    }

    #[test]
    fn try_width_rejects_out_of_range() {
        assert!(GateMask::try_width(0.5).is_ok());
        assert!(GateMask::try_width(1.0).is_ok());
        assert!(GateMask::try_width(0.1).is_ok());
        for bad in [0.0, 0.05, 1.5, -1.0, f64::NAN] {
            let e = GateMask::try_width(bad);
            assert!(e.is_err(), "fraction {bad} must be rejected");
        }
        let msg = GateMask::try_width(7.0).unwrap_err().to_string();
        assert!(msg.contains("7"), "{msg}");
    }

    #[test]
    fn branchy_yolo_simulates_end_to_end() {
        let net = zoo::yolov5l();
        let cfg = DesignConfig::uniform(&net, 2, FpRep::Int8);
        let full = simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
        assert_eq!(full.per_stage.len(), net.layers.len());
        assert!(full.latency_cycles > 0 && full.power_mw > 0.0);
        // depth morph truncates the branchy pipeline too
        let gated = simulate(&net, &cfg, &ZYNQ_7100, &GateMask::depth_prefix(&net, 4));
        assert!(gated.latency_cycles < full.latency_cycles);
        assert!(gated.power_mw < full.power_mw);
        // width morph reduces work
        let half = simulate(&net, &cfg, &ZYNQ_7100, &GateMask::width(0.5));
        assert!(half.period_cycles <= full.period_cycles);
    }
}
