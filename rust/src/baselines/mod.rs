//! Published comparison baselines (documented reference dataset).
//!
//! Tables IV and VI compare ForgeMorph against other FPGA compilers
//! (Vitis AI, hls4ml, TVM, OpenVINO) and edge devices (Jetsons, NCS,
//! Coral, ...). Those rows are *published measurements from the cited
//! systems* — not something this reproduction can regenerate without the
//! respective toolchains/hardware. Following DESIGN.md §2, we ship them
//! as a clearly-marked constant dataset: the report harness recomputes
//! every ForgeMorph row from our models/simulator and prints these
//! reference rows alongside, exactly like the paper's tables.

/// A compiler-comparison row of Table IV.
#[derive(Debug, Clone, Copy)]
pub struct CompilerRow {
    pub framework: &'static str,
    pub precision: &'static str,
    pub fps: Option<f64>,
    pub top1: Option<f64>,
    pub energy_j_frame: Option<f64>,
    pub freq_mhz: Option<f64>,
    pub fpga: &'static str,
}

/// Table IV reference rows, grouped by model.
pub const TABLE4_BASELINES: &[(&str, &[CompilerRow])] = &[
    (
        "MobileNetV2 (ImageNet)",
        &[
            CompilerRow { framework: "Vitis AI", precision: "int8", fps: Some(765.0), top1: Some(73.5), energy_j_frame: Some(0.20), freq_mhz: Some(300.0), fpga: "ZCU102" },
            CompilerRow { framework: "hls4ml", precision: "int8", fps: Some(815.7), top1: Some(73.1), energy_j_frame: Some(0.19), freq_mhz: Some(200.0), fpga: "Kintex-7" },
            CompilerRow { framework: "TVM", precision: "int8", fps: None, top1: None, energy_j_frame: None, freq_mhz: None, fpga: "NA" },
            CompilerRow { framework: "OpenVINO", precision: "int8", fps: Some(300.0), top1: Some(71.8), energy_j_frame: None, freq_mhz: Some(300.0), fpga: "Arria 10 GX 660" },
        ],
    ),
    (
        "ResNet-50 (ImageNet)",
        &[
            CompilerRow { framework: "Vitis AI", precision: "int8", fps: Some(214.0), top1: Some(76.5), energy_j_frame: Some(0.89), freq_mhz: Some(300.0), fpga: "ZCU102" },
            CompilerRow { framework: "hls4ml", precision: "int8", fps: Some(267.9), top1: Some(76.2), energy_j_frame: Some(0.40), freq_mhz: Some(200.0), fpga: "Kintex-7" },
            CompilerRow { framework: "TVM", precision: "int8", fps: Some(102.5), top1: Some(74.4), energy_j_frame: None, freq_mhz: Some(200.0), fpga: "ZCU102" },
            CompilerRow { framework: "OpenVINO", precision: "int8", fps: Some(132.3), top1: Some(75.5), energy_j_frame: None, freq_mhz: Some(300.0), fpga: "Arria 10 GX 660" },
        ],
    ),
    (
        "SqueezeNet (ImageNet)",
        &[
            CompilerRow { framework: "Vitis AI", precision: "int8", fps: Some(1527.0), top1: Some(59.3), energy_j_frame: Some(0.16), freq_mhz: Some(300.0), fpga: "ZCU102" },
            CompilerRow { framework: "hls4ml", precision: "int8", fps: Some(1610.0), top1: Some(59.0), energy_j_frame: Some(0.13), freq_mhz: Some(200.0), fpga: "Kintex-7" },
            CompilerRow { framework: "TVM", precision: "int8", fps: Some(497.5), top1: Some(59.2), energy_j_frame: None, freq_mhz: None, fpga: "NA" },
            CompilerRow { framework: "OpenVINO", precision: "int8", fps: None, top1: None, energy_j_frame: None, freq_mhz: None, fpga: "NA" },
        ],
    ),
    (
        "YOLOv5-Large (COCO 2017)",
        &[
            CompilerRow { framework: "Vitis AI", precision: "int8", fps: Some(202.0), top1: Some(60.8), energy_j_frame: Some(0.75), freq_mhz: Some(300.0), fpga: "ZCU102" },
            CompilerRow { framework: "hls4ml", precision: "int8", fps: None, top1: None, energy_j_frame: None, freq_mhz: None, fpga: "NA" },
            CompilerRow { framework: "TVM", precision: "int8", fps: Some(123.4), top1: Some(60.5), energy_j_frame: None, freq_mhz: None, fpga: "NA" },
            CompilerRow { framework: "OpenVINO", precision: "int8", fps: Some(140.0), top1: Some(61.0), energy_j_frame: None, freq_mhz: Some(300.0), fpga: "Arria 10 GX 660" },
        ],
    ),
];

/// Paper-reported ForgeMorph accuracies for Table IV (from DistillCycle
/// training on the real datasets, which we cannot rerun offline; our
/// synthetic-data accuracies live in the manifest instead).
pub const TABLE4_FORGEMORPH_TOP1: &[(&str, f64, f64, f64, f64)] = &[
    // (model, int16, int8, morph-full, morph-split)
    ("mobilenetv2", 75.1, 73.0, 70.5, 68.0),
    ("resnet50", 77.2, 76.3, 74.0, 71.8),
    ("squeezenet", 60.1, 58.9, 56.7, 55.0),
    ("yolov5l", 62.4, 60.3, f64::NAN, f64::NAN),
];

/// An edge-device row of Table VI (MLPerf-derived, MobileNetV1).
#[derive(Debug, Clone, Copy)]
pub struct EdgeRow {
    pub device: &'static str,
    pub latency_ms: f64,
    pub power_w: f64,
}

impl EdgeRow {
    /// Inferences per Watt = (1000 / latency_ms) / power_w.
    pub fn inf_per_watt(&self) -> f64 {
        (1000.0 / self.latency_ms) / self.power_w
    }
}

/// Table VI reference rows (all but the FPGA row, which we simulate).
pub const TABLE6_BASELINES: &[EdgeRow] = &[
    EdgeRow { device: "RasPi4", latency_ms: 480.3, power_w: 1.3 },
    EdgeRow { device: "NCS", latency_ms: 115.7, power_w: 2.5 },
    EdgeRow { device: "NCS2", latency_ms: 87.2, power_w: 1.5 },
    EdgeRow { device: "Jetson Nano", latency_ms: 72.3, power_w: 10.0 },
    EdgeRow { device: "Jetson TX2", latency_ms: 9.17, power_w: 15.0 },
    EdgeRow { device: "Xavier NX", latency_ms: 0.95, power_w: 20.0 },
    EdgeRow { device: "AGX Xavier", latency_ms: 0.53, power_w: 30.0 },
    EdgeRow { device: "Tinker Edge R", latency_ms: 14.6, power_w: 7.8 },
    EdgeRow { device: "Coral", latency_ms: 15.7, power_w: 5.0 },
    EdgeRow { device: "Snapdragon 888", latency_ms: 11.6, power_w: 5.0 },
];

/// Paper's FPGA (ours) row of Table VI for reference.
pub const TABLE6_PAPER_FPGA: EdgeRow =
    EdgeRow { device: "FPGA (paper)", latency_ms: 3.72, power_w: 1.53 };

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_has_all_models() {
        assert_eq!(TABLE4_BASELINES.len(), 4);
        for (model, rows) in TABLE4_BASELINES {
            assert!(!rows.is_empty(), "{model}");
        }
    }

    #[test]
    fn inf_per_watt_matches_paper() {
        // paper: AGX = 62.9 inf/W
        let agx = TABLE6_BASELINES.iter().find(|r| r.device == "AGX Xavier").unwrap();
        assert!((agx.inf_per_watt() - 62.9).abs() < 0.5, "{}", agx.inf_per_watt());
        // paper: FPGA = 178 inf/W
        assert!((TABLE6_PAPER_FPGA.inf_per_watt() - 175.7).abs() < 3.0);
    }

    #[test]
    fn vitis_resnet_reference() {
        let (_, rows) = TABLE4_BASELINES[1];
        let vitis = rows.iter().find(|r| r.framework == "Vitis AI").unwrap();
        assert_eq!(vitis.fps, Some(214.0));
        assert_eq!(vitis.energy_j_frame, Some(0.89));
    }
}
