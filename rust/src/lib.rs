//! # ForgeMorph — adaptive CNN deployment compiler (reproduction)
//!
//! Rust + JAX + Pallas reproduction of *"ForgeMorph: An FPGA Compiler for
//! On-the-Fly Adaptive CNN Reconfiguration"* (Mazouz, Le, Nguyen, 2025).
//!
//! Three-layer architecture (see DESIGN.md):
//!
//! * **L1/L2 (build time, Python)** — Pallas kernels + morphable JAX
//!   model, DistillCycle-trained and AOT-lowered to per-morph-path HLO
//!   text artifacts (`make artifacts`).
//! * **L3 (this crate)** — everything at and after deployment:
//!   * [`graph`] — dataflow-graph IR, descriptor parser, pass pipeline
//!     (canonicalize → fuse → `StagePlan`), model zoo (Table II)
//!   * [`pe`] — analytical PE models (Eqs. 1-11, Table I)
//!   * [`design`] — design-point evaluation (Eqs. 12-15)
//!   * [`dse`] — NeuroForge's multi-objective genetic DSE (Alg. 1),
//!     3-objective (latency, DSP, accuracy) when given a profile
//!   * [`distill`] — DistillCycle training engine (Alg. 2): joint
//!     full-model + subnetwork training with hierarchical KD, emitting
//!     the per-path [`distill::AccuracyProfile`]
//!   * [`fault`] — deterministic fault injection (`--fault-trace`) +
//!     self-healing: CRC scrubbing, retry backoff, shard health states
//!   * [`rtl`] — Verilog emission for selected design points
//!   * [`sim`] — cycle-level streaming simulator (the hardware stand-in)
//!   * [`morph`] — NeuroMorph runtime reconfiguration + governor
//!   * [`obs`] — structured span/event recorder: virtual-clock
//!     deterministic traces, Chrome trace-event / folded-stack /
//!     snapshot exporters (`--trace-out`, `report trace`)
//!   * [`onnx`] — offline ONNX front-end: hand-rolled protobuf wire
//!     decoder + lowering onto the graph IR (`--onnx`, docs/ONNX.md)
//!   * [`runtime`] — PJRT executor loading the AOT artifacts
//!   * [`backend`] — the unified `InferenceBackend` trait: PJRT, cycle
//!     simulator and analytical model behind one execution contract
//!   * [`coordinator`] — sharded multi-worker serving engine: per-shard
//!     queues with work stealing, dynamic batching, shared NeuroMorph
//!     governor, mergeable metrics
//!   * [`baselines`] — published comparison rows (Tables IV, VI)
//!   * [`report`] — regenerates every paper table and figure

pub mod backend;
pub mod baselines;
pub mod coordinator;
pub mod design;
pub mod distill;
pub mod dse;
pub mod fault;
pub mod graph;
pub mod morph;
pub mod obs;
pub mod onnx;
pub mod pe;
pub mod power;
pub mod quant;
pub mod report;
pub mod rtl;
pub mod runtime;
pub mod sim;
pub mod util;
