//! ONNX front-end: ingest real exported model graphs (DESIGN.md §15,
//! docs/ONNX.md).
//!
//! Two strictly separated layers, each independently testable:
//!
//! * [`proto`] — a hand-rolled protobuf **wire-format** decoder in the
//!   repo's vendored-shim style (no external crates): varint /
//!   length-delimited field walking over ModelProto -> GraphProto ->
//!   NodeProto / TensorProto. Total on arbitrary bytes — malformed
//!   input yields an offset-carrying [`DecodeError`], never a panic.
//! * [`lower`] — the op-lowering pass onto the [`crate::graph`]
//!   fork/merge IR, reproducing `NetworkBuilder` conventions exactly so
//!   an imported zoo model's `StagePlan` is **bit-identical** to its
//!   hand-built twin. Everything downstream (design/sim/rtl/dse/morph)
//!   consumes imported models with zero special-casing.
//!
//! [`export`] is the reverse direction (Network -> wire bytes), used by
//! the hermetic round-trip tests; `python/compile/export_onnx.py`
//! mirrors it for the on-disk corpus that CI diffs against `graph dump`.

pub mod export;
pub mod lower;
pub mod proto;

pub use export::encode;
pub use lower::{lower, SUPPORTED_OPS};
pub use proto::{decode_model, DecodeError, Model};

use crate::graph::Network;

/// Import failure: either the bytes are not a well-formed ONNX model
/// (offset-carrying decode error) or the graph uses constructs outside
/// the documented coverage contract (lowering error).
#[derive(Debug)]
pub enum ImportError {
    Decode(DecodeError),
    Lower(String),
}

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ImportError::Decode(e) => write!(f, "{e}"),
            ImportError::Lower(m) => write!(f, "onnx import: {m}"),
        }
    }
}

impl std::error::Error for ImportError {}

impl From<DecodeError> for ImportError {
    fn from(e: DecodeError) -> Self {
        ImportError::Decode(e)
    }
}

/// Decode + lower ONNX bytes into a validated [`Network`].
pub fn import_bytes(bytes: &[u8]) -> Result<Network, ImportError> {
    let model = decode_model(bytes)?;
    lower::lower(&model).map_err(ImportError::Lower)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn mnist_round_trips_bit_identical() {
        let twin = zoo::mnist();
        let bytes = encode(&twin).expect("zoo model encodes");
        let imported = import_bytes(&bytes).expect("exported model imports");
        assert_eq!(imported.name, twin.name);
        assert_eq!(imported.layers, twin.layers);
        assert_eq!(imported.connections, twin.connections);
    }

    #[test]
    fn garbage_bytes_error_cleanly() {
        let err = import_bytes(&[0x08]).unwrap_err();
        assert!(matches!(err, ImportError::Decode(_)), "got: {err}");
    }
}
