//! Lowering pass: decoded ONNX `GraphProto` -> [`Network`].
//!
//! The lowered network is built with **exactly** the
//! [`crate::graph::NetworkBuilder`] conventions — layer names are
//! `{op}{id}` (`conv3`, `maxpool4`, `resadd12`, ...), the connection
//! table is pushed in the same order (primary/stream edge first, then
//! skip/branch edges), and branches re-anchor the chain tail the way
//! `branch_from` does. That convention is load-bearing: an imported zoo
//! model must produce a `StagePlan` **bit-identical** to its hand-built
//! twin (`tests/onnx_import.rs` pins this), so imported models flow
//! through canonicalize -> fuse -> schedule -> design/sim/rtl/dse/morph
//! with no special-casing anywhere downstream.
//!
//! Lowering contract highlights (the full matrix lives in docs/ONNX.md):
//!
//! * `Conv` (+`group`) -> [`LayerKind::Conv`] / [`LayerKind::DwConv`];
//!   `Relu` folds into its producer when it is the sole consumer
//!   (mirroring how exporters split `conv+relu` into two nodes).
//! * `Flatten` is an alias (our FC consumes flattened features natively).
//! * A stride-1 same-padded `MaxPool` cascade re-concatenated with its
//!   own input (`Concat(x, p(x), p²(x), p³(x))`) is recognized as the
//!   SPPF idiom and fused back into [`LayerKind::SpatialPyramidPool`] —
//!   this is how YOLO-family exports spell the pyramid.
//! * Unsupported ops fail with a did-you-mean suggestion
//!   ([`crate::util::suggest`]) that names the node and its inputs.
//!
//! Only tensor *shapes* are consulted (weight dims, Resize scales): the
//! analytical mapping flow (DESIGN.md §2) never reads weight values, so
//! shape-only initializers — like the offline corpus writes — import
//! identically to full `torch.onnx.export` payloads.

use std::collections::HashMap;

use super::proto::{AttrValue, Graph, Model, Node, Tensor};
use crate::graph::{Layer, LayerKind, Network, Padding};
use crate::util::did_you_mean;

/// Every ONNX op the lowering pass accepts (suggestion source).
pub const SUPPORTED_OPS: &[&str] = &[
    "Add",
    "AveragePool",
    "Concat",
    "Conv",
    "Flatten",
    "Gemm",
    "GlobalAveragePool",
    "MaxPool",
    "Relu",
    "Resize",
    "Softmax",
    "Upsample",
];

/// A stride-1 same-padded MaxPool output waiting to be fused into an
/// SPPF stage. Taps never materialize as layers: they are only legal as
/// the `Concat(x, p(x), p²(x), p³(x))` pattern.
struct Tap {
    /// tensor name of the pyramid input `x`
    src: String,
    k: usize,
    /// 1 for `p(x)`, 2 for `p²(x)`, 3 for `p³(x)`
    depth: usize,
}

struct Lowering<'m> {
    inits: HashMap<&'m str, &'m Tensor>,
    /// tensor name -> consuming nodes + graph outputs referencing it
    consumers: HashMap<&'m str, usize>,
    /// tensor name -> producing layer id (aliases collapse here)
    producer: HashMap<String, usize>,
    taps: HashMap<String, Tap>,
    layers: Vec<Layer>,
    connections: Vec<(usize, usize)>,
    /// per-layer output channel count (attribute validation)
    ch: Vec<usize>,
    /// chain tail — the layer the next pushed layer consumes
    tail: usize,
}

/// Lower a decoded model to a validated [`Network`]. Errors are plain
/// strings; [`super::ImportError`] wraps them with import context.
pub fn lower(model: &Model) -> Result<Network, String> {
    let graph = model
        .graph
        .as_ref()
        .ok_or_else(|| "model carries no graph".to_string())?;

    let inits: HashMap<&str, &Tensor> = graph
        .initializers
        .iter()
        .map(|t| (t.name.as_str(), t))
        .collect();

    // the single data input (initializers may legally be re-listed in
    // graph.inputs; those are not data inputs)
    let data_inputs: Vec<_> = graph
        .inputs
        .iter()
        .filter(|i| !inits.contains_key(i.name.as_str()))
        .collect();
    let input = match data_inputs.as_slice() {
        [one] => *one,
        [] => return Err("graph declares no data input".into()),
        many => {
            return Err(format!(
                "graph declares {} data inputs ({}) — single-input CNNs only",
                many.len(),
                many.iter().map(|i| i.name.as_str()).collect::<Vec<_>>().join(", ")
            ))
        }
    };
    let (h, w, c) = input_dims(input)?;

    let mut consumers: HashMap<&str, usize> = HashMap::new();
    for node in &graph.nodes {
        for i in &node.inputs {
            *consumers.entry(i.as_str()).or_insert(0) += 1;
        }
    }
    for o in &graph.outputs {
        *consumers.entry(o.name.as_str()).or_insert(0) += 1;
    }

    let mut lo = Lowering {
        inits,
        consumers,
        producer: HashMap::new(),
        taps: HashMap::new(),
        layers: vec![Layer {
            id: 0,
            name: "input".into(),
            kind: LayerKind::Input { h, w, c },
        }],
        connections: Vec::new(),
        ch: vec![c],
        tail: 0,
    };
    lo.producer.insert(input.name.clone(), 0);

    for (idx, node) in graph.nodes.iter().enumerate() {
        lo.lower_node(idx, node)?;
    }

    for out in &graph.outputs {
        if !lo.producer.contains_key(&out.name) {
            let extra = if lo.taps.contains_key(&out.name) {
                " (it is a same-padded pooling tap — only an SPPF concat may consume it)"
            } else {
                ""
            };
            return Err(format!("graph output '{}' is never produced{extra}", out.name));
        }
    }

    let name = if graph.name.is_empty() { "onnx-model".to_string() } else { graph.name.clone() };
    let net = Network { name, layers: lo.layers, connections: lo.connections };
    net.validate()
        .map_err(|e| format!("imported graph failed validation: {e}"))?;
    Ok(net)
}

/// NCHW input dims with a unit (or symbolic, treated as 1) batch axis.
fn input_dims(input: &super::proto::ValueInfo) -> Result<(usize, usize, usize), String> {
    use super::proto::Dim;
    let d = &input.dims;
    if d.len() != 4 {
        return Err(format!(
            "input tensor '{}' has rank {} — expected NCHW rank 4",
            input.name,
            d.len()
        ));
    }
    match &d[0] {
        Dim::Value(1) | Dim::Param(_) => {}
        Dim::Value(n) => {
            return Err(format!(
                "input tensor '{}': batch dimension is {n} — the streaming compiler \
                 maps batch-1 frames",
                input.name
            ))
        }
    }
    let mut chw = [0usize; 3];
    for (slot, dim) in chw.iter_mut().zip(&d[1..]) {
        *slot = match dim {
            Dim::Value(v) if *v > 0 => *v as usize,
            Dim::Value(v) => {
                return Err(format!(
                    "input tensor '{}': zero-sized dimension {v} — H, W, C must be \
                     concrete and positive",
                    input.name
                ))
            }
            Dim::Param(p) => {
                return Err(format!(
                    "input tensor '{}': symbolic dimension '{p}' — H, W, C must be \
                     concrete (only the batch axis may be dynamic)",
                    input.name
                ))
            }
        };
    }
    Ok((chw[1], chw[2], chw[0]))
}

// ---------------------------------------------------------------------------
// attribute helpers
// ---------------------------------------------------------------------------

fn attr_i(node: &Node, name: &str, default: i64) -> Result<i64, String> {
    match node.attr(name) {
        None => Ok(default),
        Some(AttrValue::Int(i)) => Ok(*i),
        Some(_) => Err(format!("attribute '{name}' must be an int")),
    }
}

fn attr_f(node: &Node, name: &str, default: f32) -> Result<f32, String> {
    match node.attr(name) {
        None => Ok(default),
        Some(AttrValue::Float(f)) => Ok(*f),
        Some(AttrValue::Int(i)) => Ok(*i as f32),
        Some(_) => Err(format!("attribute '{name}' must be a float")),
    }
}

fn attr_s<'n>(node: &'n Node, name: &str, default: &'n str) -> Result<&'n str, String> {
    match node.attr(name) {
        None => Ok(default),
        Some(AttrValue::Str(s)) => Ok(s),
        Some(_) => Err(format!("attribute '{name}' must be a string")),
    }
}

fn attr_ints<'n>(node: &'n Node, name: &str) -> Result<Option<&'n [i64]>, String> {
    match node.attr(name) {
        None => Ok(None),
        Some(AttrValue::Ints(v)) => Ok(Some(v)),
        Some(_) => Err(format!("attribute '{name}' must be an int list")),
    }
}

/// `[a, a]` int-list attribute -> `a` (square spatial params only).
fn square_pair(node: &Node, name: &str, default: usize) -> Result<usize, String> {
    match attr_ints(node, name)? {
        None => Ok(default),
        Some([a, b]) if a == b && *a > 0 => Ok(*a as usize),
        Some(v) => Err(format!(
            "attribute '{name}' is {v:?} — only square (equal H/W) values are supported"
        )),
    }
}

/// Classify explicit `pads` `[t, l, b, r]` + `auto_pad` into the IR's
/// two padding modes. `k == 1` is reported as `Same` (shape-identical
/// either way; the zoo convention for 1x1 convs).
fn classify_padding(node: &Node, k: usize) -> Result<Padding, String> {
    let auto = attr_s(node, "auto_pad", "NOTSET")?;
    match auto {
        "SAME_UPPER" | "SAME_LOWER" => return Ok(Padding::Same),
        "VALID" => return Ok(Padding::Valid),
        "NOTSET" | "" => {}
        other => return Err(format!("auto_pad '{other}' is not a known mode")),
    }
    let pads = attr_ints(node, "pads")?.unwrap_or(&[0, 0, 0, 0]);
    if pads.len() != 4 {
        return Err(format!("pads {pads:?} must have 4 entries [t, l, b, r]"));
    }
    let (t, l, b, r) = (pads[0], pads[1], pads[2], pads[3]);
    if t != l || b != r {
        return Err(format!("pads {pads:?}: H/W padding must agree"));
    }
    let (lo, hi) = (t, b);
    if lo == 0 && hi == 0 {
        // k == 1: Same and Valid pad identically; report Same, the zoo
        // convention for 1x1 convs
        return Ok(if k == 1 { Padding::Same } else { Padding::Valid });
    }
    let (want_lo, want_hi) = (((k - 1) / 2) as i64, (k / 2) as i64);
    if (lo, hi) == (want_lo, want_hi) {
        return Ok(Padding::Same);
    }
    Err(format!(
        "pads {pads:?} unsupported for k={k} — zero padding (VALID) or \
         SAME-style ({want_lo}/{want_hi}) only"
    ))
}

impl<'m> Lowering<'m> {
    /// Error context naming the node like the ONNX file does.
    fn ctx(&self, idx: usize, node: &Node) -> String {
        if node.name.is_empty() {
            format!("node #{idx} ({})", node.op_type)
        } else {
            format!("node '{}' (#{idx}, {})", node.name, node.op_type)
        }
    }

    /// Resolve a consumed tensor to its producing layer id.
    fn resolve(&self, name: &str, ctx: &str) -> Result<usize, String> {
        if let Some(&p) = self.producer.get(name) {
            return Ok(p);
        }
        if self.taps.contains_key(name) {
            return Err(format!(
                "{ctx}: input '{name}' is a same-padded pooling tap — only the SPPF \
                 concat pattern Concat(x, pool(x), pool²(x), pool³(x)) may consume it"
            ));
        }
        if self.inits.contains_key(name) {
            return Err(format!(
                "{ctx}: input '{name}' is an initializer where a feature map is expected"
            ));
        }
        Err(format!(
            "{ctx}: input tensor '{name}' is not produced by any earlier node — \
             the graph is not topologically sorted, or the tensor is missing"
        ))
    }

    /// The single data output of a node.
    fn out_name<'n>(&self, node: &'n Node, ctx: &str) -> Result<&'n str, String> {
        match node.outputs.first() {
            Some(o) if !o.is_empty() => Ok(o),
            _ => Err(format!("{ctx}: node has no output tensor")),
        }
    }

    /// Append a layer consuming the current chain tail — the exact
    /// `NetworkBuilder::push` convention (name `{prefix}{id}`, edge
    /// `(tail, id)`, tail re-anchored).
    fn push(&mut self, prefix: &str, kind: LayerKind, out_ch: usize) -> usize {
        let id = self.layers.len();
        self.layers.push(Layer { id, name: format!("{prefix}{id}"), kind });
        self.connections.push((self.tail, id));
        self.ch.push(out_ch);
        self.tail = id;
        id
    }

    /// `branch_from`: re-anchor the chain tail on an earlier layer.
    fn seek(&mut self, id: usize) {
        self.tail = id;
    }

    fn lower_node(&mut self, idx: usize, node: &Node) -> Result<(), String> {
        let ctx = self.ctx(idx, node);
        match node.op_type.as_str() {
            "Conv" => self.lower_conv(node, &ctx),
            "Relu" => self.lower_relu(node, &ctx),
            "MaxPool" => self.lower_pool(node, &ctx, true),
            "AveragePool" => self.lower_pool(node, &ctx, false),
            "GlobalAveragePool" => {
                let x = self.data_input(node, &ctx)?;
                let p = self.resolve(&x, &ctx)?;
                self.seek(p);
                let c = self.ch[p];
                let id = self.push("gap", LayerKind::GlobalAvgPool, c);
                self.map_output(node, &ctx, id)
            }
            "Flatten" => {
                let axis = attr_i(node, "axis", 1).map_err(|e| format!("{ctx}: {e}"))?;
                if axis != 1 {
                    return Err(format!(
                        "{ctx}: Flatten axis {axis} unsupported (channel-major axis 1 only)"
                    ));
                }
                let x = self.data_input(node, &ctx)?;
                let p = self.resolve(&x, &ctx)?;
                // pure alias: FC consumes flattened features natively
                let out = self.out_name(node, &ctx)?.to_string();
                self.producer.insert(out, p);
                Ok(())
            }
            "Gemm" => self.lower_gemm(node, &ctx),
            "Add" => self.lower_add(node, &ctx),
            "Concat" => self.lower_concat(node, &ctx),
            "Resize" | "Upsample" => self.lower_resize(node, &ctx),
            "Softmax" => {
                let x = self.data_input(node, &ctx)?;
                let p = self.resolve(&x, &ctx)?;
                self.seek(p);
                let c = self.ch[p];
                let id = self.push("softmax", LayerKind::Softmax, c);
                self.map_output(node, &ctx, id)
            }
            "" => Err(format!("{ctx}: node has empty op_type")),
            other => {
                let hint = did_you_mean(other, SUPPORTED_OPS);
                Err(format!(
                    "{ctx}: unsupported op '{other}'{hint} — node inputs: [{}]; \
                     supported ops: {}",
                    node.inputs.join(", "),
                    SUPPORTED_OPS.join(", ")
                ))
            }
        }
    }

    /// First input, which must exist and be non-empty.
    fn data_input(&self, node: &Node, ctx: &str) -> Result<String, String> {
        match node.inputs.first() {
            Some(i) if !i.is_empty() => Ok(i.clone()),
            _ => Err(format!("{ctx}: node has no data input")),
        }
    }

    fn map_output(&mut self, node: &Node, ctx: &str, id: usize) -> Result<(), String> {
        let out = self.out_name(node, ctx)?.to_string();
        self.producer.insert(out, id);
        Ok(())
    }

    fn lower_conv(&mut self, node: &Node, ctx: &str) -> Result<(), String> {
        if !(2..=3).contains(&node.inputs.len()) {
            return Err(format!(
                "{ctx}: Conv takes X, W[, B] — got {} inputs",
                node.inputs.len()
            ));
        }
        let x = self.resolve(&node.inputs[0], ctx)?;
        let wname = &node.inputs[1];
        let w = self.inits.get(wname.as_str()).ok_or_else(|| {
            format!("{ctx}: weight '{wname}' is not a graph initializer — \
                     external or dynamic weights are unsupported")
        })?;
        let dims = &w.dims;
        if dims.len() != 4 || dims.iter().any(|&d| d <= 0) {
            return Err(format!(
                "{ctx}: weight '{wname}' has dims {dims:?} — expected positive \
                 [M, C/group, kH, kW]"
            ));
        }
        let (m, cpg, kh, kw) =
            (dims[0] as usize, dims[1] as usize, dims[2] as usize, dims[3] as usize);
        if kh != kw {
            return Err(format!("{ctx}: non-square kernel {kh}x{kw} unsupported"));
        }
        let k = kh;
        if let Some(ks) = attr_ints(node, "kernel_shape").map_err(|e| format!("{ctx}: {e}"))? {
            if ks != [k as i64, k as i64] {
                return Err(format!(
                    "{ctx}: kernel_shape {ks:?} disagrees with weight dims {dims:?}"
                ));
            }
        }
        if let Some(d) = attr_ints(node, "dilations").map_err(|e| format!("{ctx}: {e}"))? {
            if d.iter().any(|&v| v != 1) {
                return Err(format!("{ctx}: dilations {d:?} unsupported (must be 1)"));
            }
        }
        let stride = square_pair(node, "strides", 1).map_err(|e| format!("{ctx}: {e}"))?;
        let group = attr_i(node, "group", 1).map_err(|e| format!("{ctx}: {e}"))?;
        let padding = classify_padding(node, k).map_err(|e| format!("{ctx}: {e}"))?;
        let cin = self.ch[x];
        if node.inputs.len() == 3 && !node.inputs[2].is_empty() {
            if let Some(b) = self.inits.get(node.inputs[2].as_str()) {
                if b.dims != [m as i64] {
                    return Err(format!(
                        "{ctx}: bias '{}' has dims {:?} — expected [{m}]",
                        node.inputs[2], b.dims
                    ));
                }
            }
        }
        let kind = if group == 1 {
            if cpg != cin {
                return Err(format!(
                    "{ctx}: weight '{wname}' expects {cpg} input channels, but \
                     producer '{}' provides {cin}",
                    self.layers[x].name
                ));
            }
            LayerKind::Conv { filters: m, k, stride, padding, relu: false }
        } else if group as usize == cin && cpg == 1 && m == cin {
            LayerKind::DwConv { k, stride, padding, relu: false }
        } else {
            return Err(format!(
                "{ctx}: grouped convolution (group={group}, weight {dims:?}, \
                 {cin} input channels) is only supported when depthwise \
                 (group == channels, multiplier 1)"
            ));
        };
        let prefix = if group == 1 { "conv" } else { "dwconv" };
        self.seek(x);
        let out_ch = m;
        let id = self.push(prefix, kind, out_ch);
        self.map_output(node, ctx, id)
    }

    fn lower_relu(&mut self, node: &Node, ctx: &str) -> Result<(), String> {
        let x = self.data_input(node, ctx)?;
        let p = self.resolve(&x, ctx)?;
        // fold into the producing conv/FC when this relu is its *sole*
        // consumer and nothing branched in between (mirrors the pass
        // pipeline's fusion rule, but keeps builder-convention ids)
        let foldable = p == self.tail
            && p > 0
            && self.consumers.get(x.as_str()).copied().unwrap_or(0) == 1
            && matches!(
                self.layers[p].kind,
                LayerKind::Conv { relu: false, .. }
                    | LayerKind::DwConv { relu: false, .. }
                    | LayerKind::Fc { relu: false, .. }
            );
        if foldable {
            match &mut self.layers[p].kind {
                LayerKind::Conv { relu, .. }
                | LayerKind::DwConv { relu, .. }
                | LayerKind::Fc { relu, .. } => *relu = true,
                _ => unreachable!("foldable checked conv-like"),
            }
            return self.map_output(node, ctx, p);
        }
        self.seek(p);
        let c = self.ch[p];
        let id = self.push("relu", LayerKind::Relu, c);
        self.map_output(node, ctx, id)
    }

    fn lower_pool(&mut self, node: &Node, ctx: &str, is_max: bool) -> Result<(), String> {
        if node.outputs.len() > 1 && !node.outputs[1].is_empty() {
            return Err(format!("{ctx}: MaxPool Indices output unsupported"));
        }
        let k = match attr_ints(node, "kernel_shape").map_err(|e| format!("{ctx}: {e}"))? {
            Some([a, b]) if a == b && *a > 0 => *a as usize,
            Some(v) => {
                return Err(format!(
                    "{ctx}: kernel_shape {v:?} — only square windows are supported"
                ))
            }
            None => return Err(format!("{ctx}: pooling requires kernel_shape")),
        };
        let stride = square_pair(node, "strides", 1).map_err(|e| format!("{ctx}: {e}"))?;
        if attr_i(node, "ceil_mode", 0).map_err(|e| format!("{ctx}: {e}"))? != 0 {
            return Err(format!("{ctx}: ceil_mode pooling unsupported"));
        }
        let x = self.data_input(node, ctx)?;

        // SPPF tap: stride-1 same-padded MaxPool (odd k, pads (k-1)/2)
        let pads = attr_ints(node, "pads").map_err(|e| format!("{ctx}: {e}"))?;
        let auto = attr_s(node, "auto_pad", "NOTSET").map_err(|e| format!("{ctx}: {e}"))?;
        let same_padded = matches!(auto, "SAME_UPPER" | "SAME_LOWER")
            || pads.is_some_and(|p| {
                p.len() == 4 && k % 2 == 1 && p.iter().all(|&v| v == ((k - 1) / 2) as i64)
            });
        if same_padded {
            if !(is_max && stride == 1) {
                return Err(format!(
                    "{ctx}: padded pooling is only supported as the SPPF idiom \
                     (stride-1 same-padded MaxPool cascade)"
                ));
            }
            let out = self.out_name(node, ctx)?.to_string();
            let tap = if let Some(t) = self.taps.get(&x) {
                if t.k != k {
                    return Err(format!(
                        "{ctx}: pyramid window {k} disagrees with the cascade's {}",
                        t.k
                    ));
                }
                if t.depth >= 3 {
                    return Err(format!(
                        "{ctx}: pyramid cascade deeper than 3 pools unsupported"
                    ));
                }
                Tap { src: t.src.clone(), k, depth: t.depth + 1 }
            } else {
                // validates the source exists before deferring
                self.resolve(&x, ctx)?;
                Tap { src: x.clone(), k, depth: 1 }
            };
            self.taps.insert(out, tap);
            return Ok(());
        }

        if let Some(p) = pads {
            if p.iter().any(|&v| v != 0) {
                return Err(format!(
                    "{ctx}: pads {p:?} unsupported for pooling (zero pads or the \
                     SPPF idiom only)"
                ));
            }
        }
        let p = self.resolve(&x, ctx)?;
        self.seek(p);
        let c = self.ch[p];
        let (prefix, kind) = if is_max {
            ("maxpool", LayerKind::MaxPool { k, stride })
        } else {
            ("avgpool", LayerKind::AvgPool { k, stride })
        };
        let id = self.push(prefix, kind, c);
        self.map_output(node, ctx, id)
    }

    fn lower_gemm(&mut self, node: &Node, ctx: &str) -> Result<(), String> {
        if !(2..=3).contains(&node.inputs.len()) {
            return Err(format!(
                "{ctx}: Gemm takes A, B[, C] — got {} inputs",
                node.inputs.len()
            ));
        }
        let a = self.resolve(&node.inputs[0], ctx)?;
        let wname = &node.inputs[1];
        let w = self.inits.get(wname.as_str()).ok_or_else(|| {
            format!("{ctx}: weight '{wname}' is not a graph initializer")
        })?;
        if w.dims.len() != 2 || w.dims.iter().any(|&d| d <= 0) {
            return Err(format!(
                "{ctx}: weight '{wname}' has dims {:?} — expected rank-2 [out, in] \
                 or [in, out]",
                w.dims
            ));
        }
        for (name, want) in [("alpha", 1.0f32), ("beta", 1.0)] {
            let v = attr_f(node, name, want).map_err(|e| format!("{ctx}: {e}"))?;
            if (v - want).abs() > 1e-6 {
                return Err(format!("{ctx}: {name}={v} unsupported (must be 1.0)"));
            }
        }
        if attr_i(node, "transA", 0).map_err(|e| format!("{ctx}: {e}"))? != 0 {
            return Err(format!("{ctx}: transA=1 unsupported"));
        }
        let trans_b = attr_i(node, "transB", 0).map_err(|e| format!("{ctx}: {e}"))?;
        let out = match trans_b {
            1 => w.dims[0] as usize,
            0 => w.dims[1] as usize,
            other => return Err(format!("{ctx}: transB={other} is not 0/1")),
        };
        if node.inputs.len() == 3 && !node.inputs[2].is_empty() {
            if let Some(b) = self.inits.get(node.inputs[2].as_str()) {
                if b.dims != [out as i64] {
                    return Err(format!(
                        "{ctx}: bias '{}' has dims {:?} — expected [{out}]",
                        node.inputs[2], b.dims
                    ));
                }
            }
        }
        self.seek(a);
        let id = self.push("fc", LayerKind::Fc { out, relu: false }, out);
        self.map_output(node, ctx, id)
    }

    fn lower_add(&mut self, node: &Node, ctx: &str) -> Result<(), String> {
        if node.inputs.len() != 2 {
            return Err(format!("{ctx}: Add takes 2 inputs, got {}", node.inputs.len()));
        }
        for i in &node.inputs {
            if self.inits.contains_key(i.as_str()) {
                return Err(format!(
                    "{ctx}: Add with constant operand '{i}' unsupported — fold the \
                     constant into the producing layer before export"
                ));
            }
        }
        let a = self.resolve(&node.inputs[0], ctx)?;
        let b = self.resolve(&node.inputs[1], ctx)?;
        if a == b {
            return Err(format!("{ctx}: Add of a tensor with itself is not a skip merge"));
        }
        // main path = the chain tail when possible (the builder's
        // residual_add merges tail with the earlier fork); otherwise the
        // later producer is the main path
        let (main, skip) = if a == self.tail {
            (a, b)
        } else if b == self.tail {
            (b, a)
        } else {
            (a.max(b), a.min(b))
        };
        self.seek(main);
        let c = self.ch[main];
        let id = self.push("resadd", LayerKind::ResidualAdd { from: skip }, c);
        self.connections.push((skip, id));
        self.map_output(node, ctx, id)
    }

    fn lower_concat(&mut self, node: &Node, ctx: &str) -> Result<(), String> {
        let axis = attr_i(node, "axis", 1).map_err(|e| format!("{ctx}: {e}"))?;
        if axis != 1 {
            return Err(format!(
                "{ctx}: Concat axis {axis} unsupported (channel axis 1 only)"
            ));
        }
        if node.inputs.len() < 2 {
            return Err(format!(
                "{ctx}: Concat needs at least 2 inputs, has {}",
                node.inputs.len()
            ));
        }

        // SPPF fusion: Concat(x, p(x), p²(x), p³(x)) over pyramid taps
        let any_tap = node.inputs.iter().any(|i| self.taps.contains_key(i.as_str()));
        if any_tap {
            let fused = node.inputs.len() == 4
                && !self.taps.contains_key(node.inputs[0].as_str())
                && (1..4).all(|i| {
                    self.taps.get(node.inputs[i].as_str()).is_some_and(|t| {
                        t.depth == i && t.src == node.inputs[0]
                    })
                });
            if !fused {
                return Err(format!(
                    "{ctx}: same-padded pooling taps may only be consumed by the SPPF \
                     pattern Concat(x, pool(x), pool²(x), pool³(x)) — inputs: [{}]",
                    node.inputs.join(", ")
                ));
            }
            let k = self.taps[node.inputs[1].as_str()].k;
            let p = self.resolve(&node.inputs[0], ctx)?;
            self.seek(p);
            let c = 4 * self.ch[p];
            let id = self.push("sppf", LayerKind::SpatialPyramidPool { k }, c);
            return self.map_output(node, ctx, id);
        }

        let mut from = Vec::with_capacity(node.inputs.len());
        let mut c = 0usize;
        for i in &node.inputs {
            let p = self.resolve(i, ctx)?;
            c += self.ch[p];
            from.push(p);
        }
        // exact NetworkBuilder::concat convention: connected to exactly
        // the `from` list, in order; no implicit chain edge
        let id = self.layers.len();
        for &f in &from {
            self.connections.push((f, id));
        }
        self.layers.push(Layer {
            id,
            name: format!("concat{id}"),
            kind: LayerKind::Concat { from },
        });
        self.ch.push(c);
        self.tail = id;
        self.map_output(node, ctx, id)
    }

    fn lower_resize(&mut self, node: &Node, ctx: &str) -> Result<(), String> {
        let mode = attr_s(node, "mode", "nearest").map_err(|e| format!("{ctx}: {e}"))?;
        if mode != "nearest" {
            return Err(format!(
                "{ctx}: Resize mode '{mode}' unsupported (nearest-neighbour only)"
            ));
        }
        let x = self.data_input(node, ctx)?;
        let p = self.resolve(&x, ctx)?;

        // scales: a float attribute (legacy Upsample), or an initializer
        // input carrying exactly 4 floats (roi carries 8, sizes is int64)
        let scales: Vec<f32> = if let Some(AttrValue::Floats(fs)) = node.attr("scales") {
            fs.clone()
        } else {
            let mut found = None;
            for i in node.inputs.iter().skip(1) {
                if i.is_empty() {
                    continue;
                }
                if let Some(t) = self.inits.get(i.as_str()) {
                    if t.floats.len() == 4 {
                        found = Some(t.floats.clone());
                        break;
                    }
                    if !t.ints.is_empty() {
                        return Err(format!(
                            "{ctx}: sizes-based Resize unsupported — export with a \
                             'scales' input instead"
                        ));
                    }
                }
            }
            found.ok_or_else(|| {
                format!(
                    "{ctx}: Resize requires a 4-element float 'scales' initializer \
                     — inputs: [{}]",
                    node.inputs.join(", ")
                )
            })?
        };
        if scales.len() != 4 || scales[0] != 1.0 || scales[1] != 1.0 {
            return Err(format!(
                "{ctx}: scales {scales:?} must be [1, 1, f, f] (spatial-only resize)"
            ));
        }
        let (fh, fw) = (scales[2], scales[3]);
        if fh != fw || fh < 1.0 || fh.fract() != 0.0 {
            return Err(format!(
                "{ctx}: scales {scales:?} — only integer upsampling factors >= 1 \
                 with equal H/W are supported"
            ));
        }
        self.seek(p);
        let c = self.ch[p];
        let id = self.push("up", LayerKind::Upsample { factor: fh as usize }, c);
        self.map_output(node, ctx, id)
    }
}
