//! [`Network`] -> ONNX wire bytes — the reverse direction of the
//! importer, and the reason the round-trip tests are hermetic: every
//! zoo model is exported here, re-imported through `proto` + `lower`,
//! and pinned bit-identical to its hand-built twin without any file
//! fixture. The Python corpus writer (`python/compile/export_onnx.py`)
//! mirrors this emission byte for byte; CI diffs the two paths.
//!
//! Emission conventions (the lowering contract in reverse):
//!
//! * one final tensor per layer, named `t{id}`; helper nodes use
//!   suffixed intermediates (`t{id}c` conv-pre-relu, `t{id}f` flatten,
//!   `t{id}g` gemm-pre-relu, `t{id}p1..3` pyramid taps)
//! * fused relu is split into `Conv`/`Gemm` + `Relu` node pairs, the
//!   way real exporters spell it; the importer folds it back
//! * conv padding is emitted as `auto_pad` (`SAME_UPPER` / `VALID`),
//!   never a `pads` array — at `k == 1` the two modes pad identically
//!   and only `auto_pad` keeps the round trip exact
//! * [`LayerKind::SpatialPyramidPool`] becomes the SPPF idiom: three
//!   cascaded stride-1 same-padded `MaxPool`s re-concatenated with
//!   their input
//! * weight initializers are **shape-only** (dims + dtype, no payload):
//!   the analytical mapping flow never reads weight values, and this
//!   keeps the corpus small. Only `Resize` scales carry real floats.

use crate::graph::shapes::{self, Shapes};
use crate::graph::{LayerKind, Network, Padding, ShapeError};

const WIRE_VARINT: u32 = 0;
const WIRE_32: u32 = 5;
const WIRE_LEN: u32 = 2;

// AttributeProto.type enum values (written for real-consumer validity;
// our own decoder infers the type from the populated field)
const AT_FLOAT: u64 = 1;
const AT_INT: u64 = 2;
const AT_STRING: u64 = 3;
const AT_INTS: u64 = 7;

fn uv(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn tag(out: &mut Vec<u8>, field: u32, wire: u32) {
    uv(out, u64::from((field << 3) | wire));
}

fn w_vint(out: &mut Vec<u8>, field: u32, v: u64) {
    tag(out, field, WIRE_VARINT);
    uv(out, v);
}

fn w_bytes(out: &mut Vec<u8>, field: u32, b: &[u8]) {
    tag(out, field, WIRE_LEN);
    uv(out, b.len() as u64);
    out.extend_from_slice(b);
}

fn w_str(out: &mut Vec<u8>, field: u32, s: &str) {
    w_bytes(out, field, s.as_bytes());
}

fn w_f32(out: &mut Vec<u8>, field: u32, v: f32) {
    tag(out, field, WIRE_32);
    out.extend_from_slice(&v.to_le_bytes());
}

// -- AttributeProto builders ------------------------------------------------

fn attr_int(name: &str, v: u64) -> Vec<u8> {
    let mut a = Vec::new();
    w_str(&mut a, 1, name);
    w_vint(&mut a, 3, v);
    w_vint(&mut a, 20, AT_INT);
    a
}

fn attr_ints(name: &str, vals: &[usize]) -> Vec<u8> {
    let mut a = Vec::new();
    w_str(&mut a, 1, name);
    for &v in vals {
        w_vint(&mut a, 8, v as u64);
    }
    w_vint(&mut a, 20, AT_INTS);
    a
}

fn attr_str(name: &str, s: &str) -> Vec<u8> {
    let mut a = Vec::new();
    w_str(&mut a, 1, name);
    w_str(&mut a, 4, s);
    w_vint(&mut a, 20, AT_STRING);
    a
}

#[allow(dead_code)] // kept for attribute-matrix completeness
fn attr_float(name: &str, v: f32) -> Vec<u8> {
    let mut a = Vec::new();
    w_str(&mut a, 1, name);
    w_f32(&mut a, 2, v);
    w_vint(&mut a, 20, AT_FLOAT);
    a
}

// -- message builders -------------------------------------------------------

/// Append a NodeProto to the graph buffer.
fn node(g: &mut Vec<u8>, name: &str, op: &str, inputs: &[&str], outputs: &[&str], attrs: &[Vec<u8>]) {
    let mut n = Vec::new();
    for i in inputs {
        w_str(&mut n, 1, i);
    }
    for o in outputs {
        w_str(&mut n, 2, o);
    }
    w_str(&mut n, 3, name);
    w_str(&mut n, 4, op);
    for a in attrs {
        w_bytes(&mut n, 5, a);
    }
    w_bytes(g, 1, &n);
}

/// Append a shape-only float TensorProto initializer (dims + dtype, no
/// payload — the importer contract never reads weight values).
fn tensor_shape_only(g: &mut Vec<u8>, name: &str, dims: &[usize]) {
    let mut t = Vec::new();
    for &d in dims {
        w_vint(&mut t, 1, d as u64);
    }
    w_vint(&mut t, 2, super::proto::DT_FLOAT as u64);
    w_str(&mut t, 8, name);
    w_bytes(g, 5, &t);
}

/// Append a small float TensorProto with a real payload (raw_data, LE).
fn tensor_f32(g: &mut Vec<u8>, name: &str, dims: &[usize], vals: &[f32]) {
    let mut t = Vec::new();
    for &d in dims {
        w_vint(&mut t, 1, d as u64);
    }
    w_vint(&mut t, 2, super::proto::DT_FLOAT as u64);
    w_str(&mut t, 8, name);
    let mut raw = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        raw.extend_from_slice(&v.to_le_bytes());
    }
    w_bytes(&mut t, 9, &raw);
    w_bytes(g, 5, &t);
}

/// Append a ValueInfoProto (name + NCHW float tensor type) under `field`
/// (11 = graph input, 12 = graph output).
fn value_info(g: &mut Vec<u8>, field: u32, name: &str, dims: &[usize]) {
    let mut shape = Vec::new();
    for &d in dims {
        let mut dim = Vec::new();
        w_vint(&mut dim, 1, d as u64);
        w_bytes(&mut shape, 1, &dim);
    }
    let mut tt = Vec::new();
    w_vint(&mut tt, 1, super::proto::DT_FLOAT as u64);
    w_bytes(&mut tt, 2, &shape);
    let mut ty = Vec::new();
    w_bytes(&mut ty, 1, &tt);
    let mut vi = Vec::new();
    w_str(&mut vi, 1, name);
    w_bytes(&mut vi, 2, &ty);
    w_bytes(g, field, &vi);
}

fn auto_pad(p: Padding) -> &'static str {
    match p {
        Padding::Same => "SAME_UPPER",
        Padding::Valid => "VALID",
    }
}

/// Encode a network as ONNX ModelProto wire bytes (opset 13, ir 8).
/// Fails only if shape inference fails — i.e. the network itself is
/// spatially infeasible.
pub fn encode(net: &Network) -> Result<Vec<u8>, ShapeError> {
    let sh = shapes::infer(net)?;
    let preds = shapes::predecessors(net);
    let n = net.layers.len();

    let mut outdeg = vec![0usize; n];
    for &(s, d) in &net.connections {
        if s < d && d < n {
            outdeg[s] += 1;
        }
    }

    let mut g = Vec::new();
    for layer in net.layers.iter().skip(1) {
        let id = layer.id;
        let pin = preds[id].first().copied().unwrap_or(id - 1);
        let x = format!("t{pin}");
        let out = format!("t{id}");
        emit_layer(&mut g, &sh, layer, &x, &out, &preds[id]);
    }
    w_str(&mut g, 2, &net.name);

    let (h, w, c) = net.input_dims();
    value_info(&mut g, 11, "t0", &[1, c, h, w]);
    for layer in &net.layers {
        if outdeg[layer.id] == 0 {
            let o = sh.output(layer.id);
            value_info(&mut g, 12, &format!("t{}", layer.id), &[1, o.c, o.h, o.w]);
        }
    }

    let mut opset = Vec::new();
    w_vint(&mut opset, 2, 13);

    let mut m = Vec::new();
    w_vint(&mut m, 1, 8); // ir_version
    w_str(&mut m, 2, "forgemorph");
    w_str(&mut m, 3, env!("CARGO_PKG_VERSION"));
    w_bytes(&mut m, 7, &g);
    w_bytes(&mut m, 8, &opset);
    Ok(m)
}

fn emit_layer(
    g: &mut Vec<u8>,
    sh: &Shapes,
    layer: &crate::graph::Layer,
    x: &str,
    out: &str,
    preds: &[usize],
) {
    let id = layer.id;
    let name = layer.name.as_str();
    match &layer.kind {
        LayerKind::Input { .. } => unreachable!("layer 0 handled by caller"),
        LayerKind::Conv { filters, k, stride, padding, relu } => {
            let cin = sh.input_channels(id);
            let (wn, bn) = (format!("w{id}"), format!("b{id}"));
            tensor_shape_only(g, &wn, &[*filters, cin, *k, *k]);
            tensor_shape_only(g, &bn, &[*filters]);
            let conv_out = if *relu { format!("{out}c") } else { out.to_string() };
            node(
                g,
                name,
                "Conv",
                &[x, &wn, &bn],
                &[&conv_out],
                &[
                    attr_str("auto_pad", auto_pad(*padding)),
                    attr_ints("kernel_shape", &[*k, *k]),
                    attr_ints("strides", &[*stride, *stride]),
                ],
            );
            if *relu {
                node(g, &format!("{name}_relu"), "Relu", &[&conv_out], &[out], &[]);
            }
        }
        LayerKind::DwConv { k, stride, padding, relu } => {
            let cin = sh.input_channels(id);
            let (wn, bn) = (format!("w{id}"), format!("b{id}"));
            tensor_shape_only(g, &wn, &[cin, 1, *k, *k]);
            tensor_shape_only(g, &bn, &[cin]);
            let conv_out = if *relu { format!("{out}c") } else { out.to_string() };
            node(
                g,
                name,
                "Conv",
                &[x, &wn, &bn],
                &[&conv_out],
                &[
                    attr_str("auto_pad", auto_pad(*padding)),
                    attr_int("group", cin as u64),
                    attr_ints("kernel_shape", &[*k, *k]),
                    attr_ints("strides", &[*stride, *stride]),
                ],
            );
            if *relu {
                node(g, &format!("{name}_relu"), "Relu", &[&conv_out], &[out], &[]);
            }
        }
        LayerKind::MaxPool { k, stride } => {
            node(
                g,
                name,
                "MaxPool",
                &[x],
                &[out],
                &[attr_ints("kernel_shape", &[*k, *k]), attr_ints("strides", &[*stride, *stride])],
            );
        }
        LayerKind::AvgPool { k, stride } => {
            node(
                g,
                name,
                "AveragePool",
                &[x],
                &[out],
                &[attr_ints("kernel_shape", &[*k, *k]), attr_ints("strides", &[*stride, *stride])],
            );
        }
        LayerKind::GlobalAvgPool => {
            node(g, name, "GlobalAveragePool", &[x], &[out], &[]);
        }
        LayerKind::Fc { out: features, relu } => {
            let flat = format!("{out}f");
            node(g, &format!("{name}_flatten"), "Flatten", &[x], &[&flat], &[attr_int("axis", 1)]);
            let fin = sh.input_features(id);
            let (wn, bn) = (format!("w{id}"), format!("b{id}"));
            tensor_shape_only(g, &wn, &[*features, fin]);
            tensor_shape_only(g, &bn, &[*features]);
            let gemm_out = if *relu { format!("{out}g") } else { out.to_string() };
            node(g, name, "Gemm", &[&flat, &wn, &bn], &[&gemm_out], &[attr_int("transB", 1)]);
            if *relu {
                node(g, &format!("{name}_relu"), "Relu", &[&gemm_out], &[out], &[]);
            }
        }
        LayerKind::ResidualAdd { from } => {
            let skip = format!("t{from}");
            node(g, name, "Add", &[x, &skip], &[out], &[]);
        }
        LayerKind::Concat { from: _ } => {
            // preds == the explicit `from` list, in order
            let srcs: Vec<String> = preds.iter().map(|p| format!("t{p}")).collect();
            let refs: Vec<&str> = srcs.iter().map(String::as_str).collect();
            node(g, name, "Concat", &refs, &[out], &[attr_int("axis", 1)]);
        }
        LayerKind::Upsample { factor } => {
            let sc = format!("sc{id}");
            let f = *factor as f32;
            tensor_f32(g, &sc, &[4], &[1.0, 1.0, f, f]);
            node(g, name, "Resize", &[x, "", &sc], &[out], &[attr_str("mode", "nearest")]);
        }
        LayerKind::SpatialPyramidPool { k } => {
            let pad = (*k - 1) / 2;
            let pool_attrs = || {
                vec![
                    attr_ints("kernel_shape", &[*k, *k]),
                    attr_ints("pads", &[pad, pad, pad, pad]),
                    attr_ints("strides", &[1, 1]),
                ]
            };
            let taps = [format!("{out}p1"), format!("{out}p2"), format!("{out}p3")];
            let mut src = x.to_string();
            for (i, t) in taps.iter().enumerate() {
                node(g, &format!("{name}_pool{}", i + 1), "MaxPool", &[&src], &[t], &pool_attrs());
                src = t.clone();
            }
            node(
                g,
                name,
                "Concat",
                &[x, &taps[0], &taps[1], &taps[2]],
                &[out],
                &[attr_int("axis", 1)],
            );
        }
        LayerKind::Relu => {
            node(g, name, "Relu", &[x], &[out], &[]);
        }
        LayerKind::Softmax => {
            node(g, name, "Softmax", &[x], &[out], &[attr_int("axis", 1)]);
        }
    }
}
