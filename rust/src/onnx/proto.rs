//! Hand-rolled ONNX protobuf wire-format decoder.
//!
//! The offline vendor set carries no `prost`/`protobuf` crate, so —
//! matching the repo's vendored-shim style (`util::json`, the `anyhow`
//! shim) — this module walks the protobuf wire format by hand: varints,
//! length-delimited fields, fixed32/fixed64, field-number dispatch. It
//! decodes exactly the slice of the ONNX schema the lowering pass
//! ([`super::lower`]) consumes:
//!
//! ```text
//! ModelProto ── graph ──> GraphProto ── node ────────> NodeProto ── attribute ──> AttributeProto
//!                                   ├─ initializer ──> TensorProto                  │ (t / g nest)
//!                                   ├─ input/output ─> ValueInfoProto ─> TypeProto ─> TensorShapeProto
//! ```
//!
//! Unknown fields are *skipped* by wire type (forward compatibility:
//! real exporters attach doc strings, metadata props, training info),
//! but malformed wire data is a hard, offset-carrying [`DecodeError`]:
//! truncated varints, lengths past end-of-buffer, deprecated group wire
//! types, wrong wire types for known fields. Decoding is **total** — any
//! byte string returns `Ok` or `Err`, never panics (property-tested in
//! `tests/onnx_import.rs`). `AttributeProto.g` re-enters GraphProto, so
//! a recursion cap ([`MAX_GRAPH_DEPTH`]) turns crafted depth bombs into
//! errors instead of stack overflows.

/// Wire-level decode error with the byte offset where decoding failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeError {
    /// byte offset into the model buffer
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "onnx decode error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for DecodeError {}

/// Nested-graph recursion cap: `AttributeProto.g` (control-flow
/// subgraphs) re-enters `GraphProto`, so a crafted file could nest
/// graphs arbitrarily deep. Real models nest a handful of levels (If /
/// Loop bodies); past this depth we error instead of recursing.
pub const MAX_GRAPH_DEPTH: usize = 16;

// ---------------------------------------------------------------------------
// Decoded messages (the subset the importer needs)
// ---------------------------------------------------------------------------

/// Top-level `ModelProto`.
#[derive(Debug, Clone, Default)]
pub struct Model {
    pub ir_version: i64,
    pub producer_name: String,
    pub producer_version: String,
    /// `(domain, version)` pairs from `opset_import`.
    pub opsets: Vec<(String, i64)>,
    pub graph: Option<Graph>,
}

impl Model {
    /// Version of the default-domain opset (`""` or `"ai.onnx"`), if
    /// declared.
    pub fn default_opset(&self) -> Option<i64> {
        self.opsets
            .iter()
            .find(|(d, _)| d.is_empty() || d == "ai.onnx")
            .map(|&(_, v)| v)
    }
}

/// `GraphProto`.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub initializers: Vec<Tensor>,
    pub inputs: Vec<ValueInfo>,
    pub outputs: Vec<ValueInfo>,
}

/// `NodeProto`.
#[derive(Debug, Clone, Default)]
pub struct Node {
    pub name: String,
    pub op_type: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub attrs: Vec<Attr>,
}

impl Node {
    /// Attribute lookup by name.
    pub fn attr(&self, name: &str) -> Option<&AttrValue> {
        self.attrs.iter().find(|a| a.name == name).map(|a| &a.value)
    }
}

/// One `AttributeProto`.
#[derive(Debug, Clone)]
pub struct Attr {
    pub name: String,
    pub value: AttrValue,
}

/// The attribute payload variants the importer distinguishes.
#[derive(Debug, Clone)]
pub enum AttrValue {
    Int(i64),
    Float(f32),
    Str(String),
    Ints(Vec<i64>),
    Floats(Vec<f32>),
    Strs(Vec<String>),
    Tensor(Tensor),
    /// Control-flow subgraph (`If`/`Loop` bodies) — decoded so the file
    /// walks cleanly, rejected by the lowering pass.
    Graph(Graph),
}

/// `TensorProto` — dims always, values only where the importer needs
/// them (Resize scales); bulk weight payloads (`raw_data`) are length-
/// checked and skipped without being copied.
#[derive(Debug, Clone, Default)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<i64>,
    /// ONNX `TensorProto.DataType` (1 = FLOAT, 7 = INT64, ...).
    pub data_type: i64,
    /// float payload from `float_data` or a FLOAT `raw_data` small
    /// enough to matter (Resize scales); empty for shape-only tensors.
    pub floats: Vec<f32>,
    /// int payload from `int64_data`/`int32_data` or an INT64 `raw_data`
    /// (Resize `sizes`, Reshape shapes).
    pub ints: Vec<i64>,
}

/// `ValueInfoProto`: a named tensor with (possibly symbolic) dims.
#[derive(Debug, Clone, Default)]
pub struct ValueInfo {
    pub name: String,
    pub dims: Vec<Dim>,
}

/// One dimension of a `TensorShapeProto`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Dim {
    /// concrete `dim_value`
    Value(i64),
    /// symbolic `dim_param` (e.g. a dynamic batch axis named "N")
    Param(String),
}

// ---------------------------------------------------------------------------
// Wire reader
// ---------------------------------------------------------------------------

/// Protobuf wire types.
const WIRE_VARINT: u64 = 0;
const WIRE_FIXED64: u64 = 1;
const WIRE_LEN: u64 = 2;
const WIRE_SGROUP: u64 = 3;
const WIRE_EGROUP: u64 = 4;
const WIRE_FIXED32: u64 = 5;

/// Cursor over the model buffer. `base` is the cursor's offset into the
/// *whole* file, so errors inside nested length-delimited messages still
/// report absolute byte offsets.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
    base: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Reader { b, pos: 0, base: 0 }
    }

    /// Absolute offset into the original file.
    fn at(&self) -> usize {
        self.base + self.pos
    }

    fn err(&self, msg: impl Into<String>) -> DecodeError {
        DecodeError { at: self.at(), msg: msg.into() }
    }

    fn done(&self) -> bool {
        self.pos >= self.b.len()
    }

    /// LEB128 varint, at most 10 bytes. Errors on truncation and on an
    /// 11th continuation byte (overlong encoding).
    fn varint(&mut self) -> Result<u64, DecodeError> {
        let start = self.at();
        let mut v: u64 = 0;
        for i in 0..10 {
            let byte = *self
                .b
                .get(self.pos)
                .ok_or(DecodeError { at: start, msg: "truncated varint".into() })?;
            self.pos += 1;
            v |= u64::from(byte & 0x7F) << (7 * i);
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(DecodeError { at: start, msg: "varint longer than 10 bytes".into() })
    }

    /// Field key: `(field_number, wire_type)`.
    fn key(&mut self) -> Result<(u64, u64), DecodeError> {
        let at = self.at();
        let k = self.varint()?;
        let field = k >> 3;
        if field == 0 {
            return Err(DecodeError { at, msg: "field number 0 is reserved".into() });
        }
        Ok((field, k & 0x7))
    }

    /// Length-delimited payload as a sub-slice.
    fn bytes(&mut self) -> Result<&'a [u8], DecodeError> {
        let at = self.at();
        let len = self.varint()? as usize;
        let end = self.pos.checked_add(len).filter(|&e| e <= self.b.len()).ok_or(
            DecodeError {
                at,
                msg: format!("length {len} runs past end of buffer"),
            },
        )?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// A nested reader over a length-delimited payload, offset-anchored.
    fn nested(&mut self) -> Result<Reader<'a>, DecodeError> {
        let abs = self.base;
        let start_of_payload = {
            let before = self.pos;
            let s = self.bytes()?;
            // position of the payload start = cursor before - but bytes()
            // consumed the length varint first; recompute from slice ptr
            let consumed_len_bytes = self.pos - before - s.len();
            before + consumed_len_bytes
        };
        // re-slice (bytes() already advanced self.pos to the end)
        let payload = &self.b[start_of_payload..self.pos];
        Ok(Reader { b: payload, pos: 0, base: abs + start_of_payload })
    }

    fn string(&mut self) -> Result<String, DecodeError> {
        let at = self.at();
        let s = self.bytes()?;
        String::from_utf8(s.to_vec())
            .map_err(|_| DecodeError { at, msg: "invalid utf-8 in string field".into() })
    }

    fn fixed32(&mut self) -> Result<u32, DecodeError> {
        let at = self.at();
        let s = self
            .b
            .get(self.pos..self.pos + 4)
            .ok_or(DecodeError { at, msg: "truncated fixed32".into() })?;
        self.pos += 4;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn fixed64(&mut self) -> Result<u64, DecodeError> {
        let at = self.at();
        let s = self
            .b
            .get(self.pos..self.pos + 8)
            .ok_or(DecodeError { at, msg: "truncated fixed64".into() })?;
        self.pos += 8;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    /// Skip one field payload of the given wire type (unknown fields).
    fn skip(&mut self, wire: u64) -> Result<(), DecodeError> {
        match wire {
            WIRE_VARINT => {
                self.varint()?;
            }
            WIRE_FIXED64 => {
                self.fixed64()?;
            }
            WIRE_LEN => {
                self.bytes()?;
            }
            WIRE_FIXED32 => {
                self.fixed32()?;
            }
            WIRE_SGROUP | WIRE_EGROUP => {
                return Err(self.err("deprecated group wire type"));
            }
            other => return Err(self.err(format!("invalid wire type {other}"))),
        }
        Ok(())
    }

    /// A known field expected at wire type `want`; anything else is a
    /// hard error naming the mismatch (never silently mis-read).
    fn expect(&self, field: u64, wire: u64, want: u64, msg: &str) -> Result<(), DecodeError> {
        if wire == want {
            Ok(())
        } else {
            Err(self.err(format!(
                "field {field} ({msg}): expected wire type {want}, got {wire}"
            )))
        }
    }

    /// Repeated scalar varint field that may arrive packed (wire type 2)
    /// or unpacked (wire type 0); appends into `out`.
    fn repeated_varint(
        &mut self,
        wire: u64,
        out: &mut Vec<i64>,
    ) -> Result<(), DecodeError> {
        match wire {
            WIRE_VARINT => out.push(self.varint()? as i64),
            WIRE_LEN => {
                let mut sub = self.nested()?;
                while !sub.done() {
                    out.push(sub.varint()? as i64);
                }
            }
            other => return Err(self.err(format!("repeated int: bad wire type {other}"))),
        }
        Ok(())
    }

    /// Repeated float field, packed or unpacked.
    fn repeated_float(&mut self, wire: u64, out: &mut Vec<f32>) -> Result<(), DecodeError> {
        match wire {
            WIRE_FIXED32 => out.push(f32::from_bits(self.fixed32()?)),
            WIRE_LEN => {
                let at = self.at();
                let s = self.bytes()?;
                if s.len() % 4 != 0 {
                    return Err(DecodeError {
                        at,
                        msg: format!("packed float payload of {} bytes (not /4)", s.len()),
                    });
                }
                for c in s.chunks_exact(4) {
                    out.push(f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])));
                }
            }
            other => return Err(self.err(format!("repeated float: bad wire type {other}"))),
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Message decoders
// ---------------------------------------------------------------------------

/// Decode a complete `ModelProto` from raw bytes. Total: returns
/// `Ok(Model)` or an offset-carrying [`DecodeError`]; never panics.
pub fn decode_model(bytes: &[u8]) -> Result<Model, DecodeError> {
    let mut r = Reader::new(bytes);
    let mut m = Model::default();
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => {
                r.expect(field, wire, WIRE_VARINT, "ir_version")?;
                m.ir_version = r.varint()? as i64;
            }
            2 => {
                r.expect(field, wire, WIRE_LEN, "producer_name")?;
                m.producer_name = r.string()?;
            }
            3 => {
                r.expect(field, wire, WIRE_LEN, "producer_version")?;
                m.producer_version = r.string()?;
            }
            7 => {
                r.expect(field, wire, WIRE_LEN, "graph")?;
                let mut sub = r.nested()?;
                m.graph = Some(decode_graph(&mut sub, 0)?);
            }
            8 => {
                r.expect(field, wire, WIRE_LEN, "opset_import")?;
                let mut sub = r.nested()?;
                m.opsets.push(decode_opset(&mut sub)?);
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(m)
}

/// `OperatorSetIdProto`: domain (1), version (2).
fn decode_opset(r: &mut Reader) -> Result<(String, i64), DecodeError> {
    let (mut domain, mut version) = (String::new(), 0i64);
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => {
                r.expect(field, wire, WIRE_LEN, "opset domain")?;
                domain = r.string()?;
            }
            2 => {
                r.expect(field, wire, WIRE_VARINT, "opset version")?;
                version = r.varint()? as i64;
            }
            _ => r.skip(wire)?,
        }
    }
    Ok((domain, version))
}

fn decode_graph(r: &mut Reader, depth: usize) -> Result<Graph, DecodeError> {
    if depth >= MAX_GRAPH_DEPTH {
        return Err(r.err(format!(
            "graph nesting exceeds depth {MAX_GRAPH_DEPTH} (malicious or corrupt file)"
        )));
    }
    let mut g = Graph::default();
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => {
                r.expect(field, wire, WIRE_LEN, "node")?;
                let mut sub = r.nested()?;
                g.nodes.push(decode_node(&mut sub, depth)?);
            }
            2 => {
                r.expect(field, wire, WIRE_LEN, "graph name")?;
                g.name = r.string()?;
            }
            5 => {
                r.expect(field, wire, WIRE_LEN, "initializer")?;
                let mut sub = r.nested()?;
                g.initializers.push(decode_tensor(&mut sub)?);
            }
            11 => {
                r.expect(field, wire, WIRE_LEN, "input")?;
                let mut sub = r.nested()?;
                g.inputs.push(decode_value_info(&mut sub)?);
            }
            12 => {
                r.expect(field, wire, WIRE_LEN, "output")?;
                let mut sub = r.nested()?;
                g.outputs.push(decode_value_info(&mut sub)?);
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(g)
}

fn decode_node(r: &mut Reader, depth: usize) -> Result<Node, DecodeError> {
    let mut n = Node::default();
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => {
                r.expect(field, wire, WIRE_LEN, "node input")?;
                n.inputs.push(r.string()?);
            }
            2 => {
                r.expect(field, wire, WIRE_LEN, "node output")?;
                n.outputs.push(r.string()?);
            }
            3 => {
                r.expect(field, wire, WIRE_LEN, "node name")?;
                n.name = r.string()?;
            }
            4 => {
                r.expect(field, wire, WIRE_LEN, "op_type")?;
                n.op_type = r.string()?;
            }
            5 => {
                r.expect(field, wire, WIRE_LEN, "attribute")?;
                let mut sub = r.nested()?;
                n.attrs.push(decode_attr(&mut sub, depth)?);
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(n)
}

fn decode_attr(r: &mut Reader, depth: usize) -> Result<Attr, DecodeError> {
    let mut name = String::new();
    let mut value: Option<AttrValue> = None;
    let mut ints: Vec<i64> = Vec::new();
    let mut floats: Vec<f32> = Vec::new();
    let mut strs: Vec<String> = Vec::new();
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => {
                r.expect(field, wire, WIRE_LEN, "attr name")?;
                name = r.string()?;
            }
            2 => {
                r.expect(field, wire, WIRE_FIXED32, "attr f")?;
                value = Some(AttrValue::Float(f32::from_bits(r.fixed32()?)));
            }
            3 => {
                r.expect(field, wire, WIRE_VARINT, "attr i")?;
                value = Some(AttrValue::Int(r.varint()? as i64));
            }
            4 => {
                r.expect(field, wire, WIRE_LEN, "attr s")?;
                value = Some(AttrValue::Str(r.string()?));
            }
            5 => {
                r.expect(field, wire, WIRE_LEN, "attr t")?;
                let mut sub = r.nested()?;
                value = Some(AttrValue::Tensor(decode_tensor(&mut sub)?));
            }
            6 => {
                r.expect(field, wire, WIRE_LEN, "attr g")?;
                let mut sub = r.nested()?;
                value = Some(AttrValue::Graph(decode_graph(&mut sub, depth + 1)?));
            }
            7 => r.repeated_float(wire, &mut floats)?,
            8 => r.repeated_varint(wire, &mut ints)?,
            9 => {
                r.expect(field, wire, WIRE_LEN, "attr strings")?;
                strs.push(r.string()?);
            }
            _ => r.skip(wire)?,
        }
    }
    let value = if let Some(v) = value {
        v
    } else if !ints.is_empty() {
        AttrValue::Ints(ints)
    } else if !floats.is_empty() {
        AttrValue::Floats(floats)
    } else if !strs.is_empty() {
        AttrValue::Strs(strs)
    } else {
        // an empty repeated list is a legitimate attribute value
        AttrValue::Ints(Vec::new())
    };
    Ok(Attr { name, value })
}

/// ONNX `TensorProto.DataType.FLOAT`.
pub const DT_FLOAT: i64 = 1;
/// ONNX `TensorProto.DataType.INT64`.
pub const DT_INT64: i64 = 7;

fn decode_tensor(r: &mut Reader) -> Result<Tensor, DecodeError> {
    let mut t = Tensor::default();
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => r.repeated_varint(wire, &mut t.dims)?,
            2 => {
                r.expect(field, wire, WIRE_VARINT, "data_type")?;
                t.data_type = r.varint()? as i64;
            }
            4 => r.repeated_float(wire, &mut t.floats)?,
            5 | 7 => r.repeated_varint(wire, &mut t.ints)?,
            8 => {
                r.expect(field, wire, WIRE_LEN, "tensor name")?;
                t.name = r.string()?;
            }
            9 => {
                r.expect(field, wire, WIRE_LEN, "raw_data")?;
                let at = r.at();
                let raw = r.bytes()?;
                // bulk weight payloads are skipped; small payloads the
                // importer can need (Resize scales, Reshape shapes) are
                // decoded by declared element type
                const SMALL: usize = 256;
                if raw.len() <= SMALL {
                    match t.data_type {
                        DT_FLOAT if raw.len() % 4 == 0 => {
                            for c in raw.chunks_exact(4) {
                                t.floats.push(f32::from_bits(u32::from_le_bytes([
                                    c[0], c[1], c[2], c[3],
                                ])));
                            }
                        }
                        DT_INT64 if raw.len() % 8 == 0 => {
                            for c in raw.chunks_exact(8) {
                                t.ints.push(i64::from_le_bytes([
                                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                                ]));
                            }
                        }
                        DT_FLOAT | DT_INT64 => {
                            return Err(DecodeError {
                                at,
                                msg: format!(
                                    "raw_data of {} bytes does not divide its element size",
                                    raw.len()
                                ),
                            });
                        }
                        _ => {} // other dtypes: shape-only is all we need
                    }
                }
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(t)
}

fn decode_value_info(r: &mut Reader) -> Result<ValueInfo, DecodeError> {
    let mut v = ValueInfo::default();
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => {
                r.expect(field, wire, WIRE_LEN, "value name")?;
                v.name = r.string()?;
            }
            2 => {
                r.expect(field, wire, WIRE_LEN, "type")?;
                let mut sub = r.nested()?;
                decode_type(&mut sub, &mut v.dims)?;
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(v)
}

/// `TypeProto` -> `tensor_type` (1) -> `TypeProto.Tensor`:
/// elem_type (1), shape (2) -> `TensorShapeProto` -> dim (1).
fn decode_type(r: &mut Reader, dims: &mut Vec<Dim>) -> Result<(), DecodeError> {
    while !r.done() {
        let (field, wire) = r.key()?;
        if field == 1 && wire == WIRE_LEN {
            let mut tt = r.nested()?;
            while !tt.done() {
                let (f2, w2) = tt.key()?;
                if f2 == 2 && w2 == WIRE_LEN {
                    let mut shape = tt.nested()?;
                    while !shape.done() {
                        let (f3, w3) = shape.key()?;
                        if f3 == 1 && w3 == WIRE_LEN {
                            let mut d = shape.nested()?;
                            dims.push(decode_dim(&mut d)?);
                        } else {
                            shape.skip(w3)?;
                        }
                    }
                } else {
                    tt.skip(w2)?;
                }
            }
        } else {
            r.skip(wire)?;
        }
    }
    Ok(())
}

fn decode_dim(r: &mut Reader) -> Result<Dim, DecodeError> {
    let mut dim = Dim::Value(0); // absent dim_value decodes as 0 (proto3 default)
    while !r.done() {
        let (field, wire) = r.key()?;
        match field {
            1 => {
                r.expect(field, wire, WIRE_VARINT, "dim_value")?;
                dim = Dim::Value(r.varint()? as i64);
            }
            2 => {
                r.expect(field, wire, WIRE_LEN, "dim_param")?;
                dim = Dim::Param(r.string()?);
            }
            _ => r.skip(wire)?,
        }
    }
    Ok(dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// varint encoder for hand-built wire fixtures
    fn v(mut n: u64) -> Vec<u8> {
        let mut out = Vec::new();
        loop {
            let b = (n & 0x7F) as u8;
            n >>= 7;
            if n == 0 {
                out.push(b);
                return out;
            }
            out.push(b | 0x80);
        }
    }

    fn key(field: u64, wire: u64) -> Vec<u8> {
        v((field << 3) | wire)
    }

    fn ld(field: u64, payload: &[u8]) -> Vec<u8> {
        let mut out = key(field, WIRE_LEN);
        out.extend(v(payload.len() as u64));
        out.extend_from_slice(payload);
        out
    }

    fn vint(field: u64, value: u64) -> Vec<u8> {
        let mut out = key(field, WIRE_VARINT);
        out.extend(v(value));
        out
    }

    #[test]
    fn decodes_minimal_model() {
        // ModelProto{ ir_version: 8, graph: Graph{ name: "g",
        //   node: [Node{ op_type: "Relu", input: ["x"], output: ["y"] }] } }
        let node = [ld(1, b"x"), ld(2, b"y"), ld(4, b"Relu")].concat();
        let graph = [ld(2, b"g"), ld(1, &node)].concat();
        let model = [vint(1, 8), ld(7, &graph)].concat();
        let m = decode_model(&model).unwrap();
        assert_eq!(m.ir_version, 8);
        let g = m.graph.unwrap();
        assert_eq!(g.name, "g");
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.nodes[0].op_type, "Relu");
        assert_eq!(g.nodes[0].inputs, vec!["x"]);
    }

    #[test]
    fn decodes_attrs_packed_and_unpacked() {
        // kernel_shape ints packed [3,3]; stride ints unpacked 2,2
        let packed = [ld(1, b"kernel_shape"), ld(8, &[v(3), v(3)].concat())].concat();
        let unpacked =
            [ld(1, b"strides"), vint(8, 2), vint(8, 2)].concat();
        let node = [ld(4, b"MaxPool"), ld(5, &packed), ld(5, &unpacked)].concat();
        let graph = ld(1, &node);
        let model = ld(7, &graph);
        let m = decode_model(&model).unwrap();
        let n = &m.graph.unwrap().nodes[0];
        match n.attr("kernel_shape") {
            Some(AttrValue::Ints(ks)) => assert_eq!(ks, &vec![3, 3]),
            other => panic!("bad kernel_shape: {other:?}"),
        }
        match n.attr("strides") {
            Some(AttrValue::Ints(st)) => assert_eq!(st, &vec![2, 2]),
            other => panic!("bad strides: {other:?}"),
        }
    }

    #[test]
    fn truncated_varint_carries_offset() {
        // field 1 varint whose continuation bit never clears
        let bytes = [0x08, 0xFF];
        let e = decode_model(&bytes).unwrap_err();
        assert_eq!(e.at, 1, "{e}");
        assert!(e.msg.contains("truncated varint"), "{e}");
    }

    #[test]
    fn length_past_end_carries_offset() {
        // graph field claims 100 payload bytes, none present
        let mut bytes = key(7, WIRE_LEN);
        bytes.extend(v(100));
        let e = decode_model(&bytes).unwrap_err();
        assert!(e.msg.contains("runs past end"), "{e}");
        assert_eq!(e.at, 1, "{e}");
    }

    #[test]
    fn wrong_wire_type_is_an_error() {
        // ModelProto.graph (field 7) as a varint instead of length-delim
        let bytes = vint(7, 1);
        let e = decode_model(&bytes).unwrap_err();
        assert!(e.msg.contains("wire type"), "{e}");
    }

    #[test]
    fn group_wire_type_rejected() {
        let bytes = key(9, WIRE_SGROUP);
        let e = decode_model(&bytes).unwrap_err();
        assert!(e.msg.contains("group"), "{e}");
    }

    #[test]
    fn depth_bomb_errors_without_overflow() {
        // attr g nesting: graph{node{attr{g: graph{node{attr{g: ...}}}}}}
        let mut graph: Vec<u8> = ld(2, b"leaf");
        for _ in 0..64 {
            let attr = [ld(1, b"body"), ld(6, &graph)].concat();
            let node = [ld(4, b"If"), ld(5, &attr)].concat();
            graph = ld(1, &node);
        }
        let model = ld(7, &graph);
        let e = decode_model(&model).unwrap_err();
        assert!(e.msg.contains("nesting exceeds depth"), "{e}");
    }

    #[test]
    fn unknown_fields_are_skipped() {
        // doc_string (field 6 of ModelProto) + an unknown high field
        let graph = ld(2, b"g");
        let model =
            [ld(6, b"some docs"), vint(99, 7), ld(7, &graph)].concat();
        let m = decode_model(&model).unwrap();
        assert_eq!(m.graph.unwrap().name, "g");
    }

    #[test]
    fn tensor_dims_and_small_raw_data() {
        // initializer: dims [1,1,2,2], FLOAT raw_data = scales [1,1,2,2]
        let floats: Vec<u8> = [1.0f32, 1.0, 2.0, 2.0]
            .iter()
            .flat_map(|f| f.to_le_bits_vec())
            .collect();
        let tensor = [
            ld(8, b"scales"),
            vint(2, DT_FLOAT as u64),
            ld(1, &[v(1), v(1), v(2), v(2)].concat()),
            ld(9, &floats),
        ]
        .concat();
        let graph = ld(5, &tensor);
        let model = ld(7, &graph);
        let m = decode_model(&model).unwrap();
        let t = &m.graph.unwrap().initializers[0];
        assert_eq!(t.dims, vec![1, 1, 2, 2]);
        assert_eq!(t.floats, vec![1.0, 1.0, 2.0, 2.0]);
    }

    trait F32Bits {
        fn to_le_bits_vec(&self) -> Vec<u8>;
    }
    impl F32Bits for f32 {
        fn to_le_bits_vec(&self) -> Vec<u8> {
            self.to_le_bytes().to_vec()
        }
    }

    #[test]
    fn empty_input_is_a_valid_empty_model() {
        let m = decode_model(&[]).unwrap();
        assert!(m.graph.is_none());
    }

    #[test]
    fn value_info_dims_decode() {
        // input "x" with dims [1, "N", 28]
        let dim1 = vint(1, 1);
        let dim2 = ld(2, b"N");
        let dim3 = vint(1, 28);
        let shape = [ld(1, &dim1), ld(1, &dim2), ld(1, &dim3)].concat();
        let tensor_type = ld(2, &shape);
        let typ = ld(1, &tensor_type);
        let vi = [ld(1, b"x"), ld(2, &typ)].concat();
        let graph = ld(11, &vi);
        let model = ld(7, &graph);
        let m = decode_model(&model).unwrap();
        let inp = &m.graph.unwrap().inputs[0];
        assert_eq!(inp.name, "x");
        assert_eq!(
            inp.dims,
            vec![Dim::Value(1), Dim::Param("N".into()), Dim::Value(28)]
        );
    }
}
