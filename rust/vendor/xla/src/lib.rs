//! Offline stub of the `xla` (xla_extension) PJRT binding.
//!
//! The container image carries no native XLA/PJRT libraries, so this
//! path crate mirrors the exact API surface `forgemorph::runtime` calls
//! and fails gracefully at the first entry point ([`PjRtClient::cpu`]).
//! The PJRT backend therefore reports a clean initialization error
//! instead of a link failure, and every artifact-gated test/bench skips.
//!
//! To run against real hardware, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual binding; no `forgemorph` source
//! changes are needed — the call signatures below are kept identical.

use std::fmt;

/// Error type matching `xla::Error`'s role (display-able, std error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT unavailable: built with the offline xla stub (swap \
         rust/vendor/xla for the real xla_extension binding)"
            .to_string(),
    )
}

/// Stub PJRT client. [`PjRtClient::cpu`] always fails in stub builds.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module handle (never constructible in stub builds).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Computation wrapper over an HLO proto.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Host literal (construction works; device round-trips do not).
pub struct Literal {
    data: Vec<f32>,
}

impl Literal {
    pub fn vec1(values: &[f32]) -> Literal {
        Literal { data: values.to_vec() }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal { data: self.data.clone() })
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_init_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("offline xla stub"));
    }

    #[test]
    fn literal_roundtrip_shapes() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[2, 2]).is_ok());
    }
}
