//! Minimal, dependency-free shim of the `anyhow` error-handling API.
//!
//! The offline build environment carries no registry crates, so this
//! local path crate provides the exact subset the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and [`ensure!`]
//! macros, and the [`Context`] extension trait for `Result`/`Option`.
//!
//! Semantics intentionally mirror the real crate where it matters:
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?` (blanket `From`);
//! * [`Error`] itself does NOT implement `std::error::Error`, so the
//!   blanket impl cannot overlap the reflexive `From<Error>`;
//! * context is prepended to the underlying message.

use std::fmt;

/// A type-erased error: a display chain captured at conversion time.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with a context line (used by [`Context`]).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to failing `Result`s and empty `Option`s.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<u8> {
        let r: std::result::Result<u8, std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"));
        let v = r?;
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macros_format() {
        let e: Error = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable branch");
        }
        assert_eq!(inner(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(inner(true).unwrap_err().to_string(), "unreachable branch");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing report").unwrap_err();
        assert!(e.to_string().starts_with("writing report: "));
        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "model")).unwrap_err();
        assert_eq!(e.to_string(), "missing model");
    }
}
