//! Integration: the sharded serving engine over the unified backends.
//!
//! Pins the refactor's core guarantees:
//! * determinism under sharding — the same request stream produces
//!   identical logits and decisions on 1 worker and 4 workers, for both
//!   the sim and the analytical backend (and the two backends agree with
//!   each other, since they share the surrogate classifier);
//! * globally consistent morphing — a budget squeeze downshifts every
//!   shard exactly once;
//! * lifecycle — shutdown drains all in-flight requests.

use std::time::Duration;

use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{Coordinator, ResponseStatus, ServeConfig};
use forgemorph::design::DesignConfig;
use forgemorph::fault::FaultDirective;
use forgemorph::graph::zoo;
use forgemorph::morph;
use forgemorph::morph::governor::Budget;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::sim::{self, GateMask};
use forgemorph::util::rng::Rng;

fn request_stream(n: usize, frame_len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(99);
    (0..n)
        .map(|_| (0..frame_len).map(|_| rng.f64() as f32).collect())
        .collect()
}

fn spec_for(kind: &str) -> BackendSpec {
    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
    let paths = morph::depth_ladder(&net);
    match kind {
        "sim" => BackendSpec::sim(net, design, ZYNQ_7100, paths),
        "analytical" => BackendSpec::analytical(net, design, ZYNQ_7100, paths),
        other => panic!("unknown backend kind {other}"),
    }
}

/// Serve `stream` and return (logits, class, path) per request, in
/// submission order.
fn serve(
    kind: &str,
    workers: usize,
    stream: &[Vec<f32>],
) -> Vec<(Vec<f32>, usize, String)> {
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        patience: 1,
        workers,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec_for(kind)).expect("start");
    let rxs: Vec<_> = stream
        .iter()
        .map(|f| coord.submit(f.clone()).expect("submit"))
        .collect();
    let out = rxs
        .into_iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(60)).expect("response");
            (r.logits, r.class, r.path)
        })
        .collect();
    coord.shutdown();
    out
}

#[test]
fn identical_results_across_backends_and_worker_counts() {
    let stream = request_stream(48, 784);
    let reference = serve("sim", 1, &stream);
    assert_eq!(reference.len(), 48);
    // unconstrained budget: every request rides the full path
    assert!(reference.iter().all(|(_, _, p)| p == "d3_w100"));

    for (kind, workers) in [("sim", 4), ("analytical", 1), ("analytical", 4)] {
        let got = serve(kind, workers, &stream);
        assert_eq!(got.len(), reference.len());
        for (i, (r, g)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(
                r.0, g.0,
                "request {i}: logits diverge on {kind} backend at {workers} workers"
            );
            assert_eq!(r.1, g.1, "request {i}: class decision diverges");
            assert_eq!(r.2, g.2, "request {i}: morph path diverges");
        }
    }
}

#[test]
fn budget_squeeze_downshifts_all_shards_once() {
    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
    let full_power =
        sim::simulate(&net, &design, &ZYNQ_7100, &GateMask::all_active()).power_mw;
    let stream = request_stream(32, 784);

    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        patience: 1,
        workers: 4,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec_for("sim")).expect("start");

    // phase 1: unconstrained -> full path everywhere
    let rxs: Vec<_> = stream
        .iter()
        .map(|f| coord.submit(f.clone()).expect("submit"))
        .collect();
    for rx in rxs {
        assert_eq!(rx.recv_timeout(Duration::from_secs(60)).unwrap().path, "d3_w100");
    }

    // phase 2: squeeze. Governor observation is batch-paced, so the
    // first batch taken after this observes the violation (patience 1)
    // and the shared governor moves every shard to the same cheaper path
    coord
        .set_budget(Budget { power_mw: Some(full_power - 40.0), latency_ms: None })
        .expect("set_budget");
    let rxs: Vec<_> = stream
        .iter()
        .map(|f| coord.submit(f.clone()).expect("submit"))
        .collect();
    let mut phase2_paths = std::collections::BTreeSet::new();
    for rx in rxs {
        phase2_paths.insert(rx.recv_timeout(Duration::from_secs(60)).unwrap().path);
    }
    let metrics = coord.shutdown();
    assert_eq!(
        phase2_paths.len(),
        1,
        "shards disagree on the active path: {phase2_paths:?}"
    );
    assert_ne!(phase2_paths.iter().next().unwrap(), "d3_w100");
    assert_eq!(metrics.morph_switches, 1, "exactly one global downshift");
    assert_eq!(metrics.requests, 64);
}

#[test]
fn shutdown_drains_inflight_requests() {
    let stream = request_stream(30, 784);
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(5),
        patience: 2,
        workers: 2,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec_for("sim")).expect("start");
    let rxs: Vec<_> = stream
        .iter()
        .map(|f| coord.submit(f.clone()).expect("submit"))
        .collect();
    // shut down immediately: every queued request must still be answered
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 30, "in-flight requests dropped at shutdown");
    let mut answered = 0;
    for rx in rxs {
        if rx.recv_timeout(Duration::from_secs(1)).is_ok() {
            answered += 1;
        }
    }
    assert_eq!(answered, 30);
}

#[test]
fn exhausted_retries_yield_terminal_failed_not_a_hang() {
    // regression: an execute failure used to drop the request on the
    // floor, leaving the submitter blocked on the reply channel forever.
    // A fault that outlives the retry budget must resolve as Failed.
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        patience: 2,
        workers: 2,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec_for("sim")).expect("start");
    let frame = request_stream(1, 784).pop().unwrap();
    let rx = coord
        .submit_with_fault(frame.clone(), FaultDirective { stall_ms: 0.0, fail_attempts: 99 })
        .expect("submit");
    let resp = rx.recv_timeout(Duration::from_secs(10)).expect("terminal response");
    assert!(resp.status.is_failed(), "status: {:?}", resp.status);
    // default RetryPolicy allows 2 retries -> 3 attempts total
    assert_eq!(resp.attempts, 3);
    assert!(resp.logits.is_empty(), "failed responses carry no logits");

    // a transient that heals within the budget recovers to Ok
    let rx = coord
        .submit_with_fault(frame, FaultDirective { stall_ms: 0.0, fail_attempts: 1 })
        .expect("submit");
    let resp = rx.recv_timeout(Duration::from_secs(10)).expect("terminal response");
    assert!(resp.status.is_ok(), "status: {:?}", resp.status);
    assert_eq!(resp.attempts, 2, "one failed attempt + one successful retry");
    assert_eq!(resp.logits.len(), 10);

    let metrics = coord.shutdown();
    assert!(metrics.retries >= 3, "retries uncounted: {}", metrics.retries);
    assert_eq!(metrics.failed_requests, 1);
}

#[test]
fn expired_deadline_fails_terminally() {
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        patience: 2,
        workers: 1,
        request_deadline: Some(Duration::ZERO),
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec_for("sim")).expect("start");
    let rxs: Vec<_> = request_stream(8, 784)
        .into_iter()
        .map(|f| coord.submit(f).expect("submit"))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("terminal response");
        match resp.status {
            ResponseStatus::Failed { ref reason } => {
                assert!(reason.contains("deadline"), "unexpected reason: {reason}")
            }
            ref other => panic!("expected deadline failure, got {other:?}"),
        }
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.timeouts, 8);
    assert_eq!(metrics.failed_requests, 8);
}

#[test]
fn shutdown_during_swap_completes_pinned_runs() {
    // pinned requests straddling a path boundary emulate shutdown landing
    // mid drain→swap: both runs must complete on their pinned paths
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(5),
        patience: 2,
        workers: 2,
        external_pacing: true,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec_for("sim")).expect("start");
    let stream = request_stream(24, 784);
    let rxs: Vec<_> = stream
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let path = if i < 12 { "d3_w100" } else { "d1_w100" };
            coord.submit_pinned(f.clone(), path.to_string()).expect("submit")
        })
        .collect();
    // shut down immediately: the outgoing-path run is still draining
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 24, "pinned requests dropped at shutdown");
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(1)).expect("response");
        let want = if i < 12 { "d3_w100" } else { "d1_w100" };
        assert_eq!(resp.path, want, "request {i} answered off its pinned path");
        assert!(resp.status.is_ok());
    }
}

#[test]
fn work_stealing_spreads_load_across_shards() {
    // flood 4 shards with batch-ripe queues; every shard should end up
    // executing (no idle worker while neighbours are backlogged)
    let stream = request_stream(256, 784);
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        patience: 2,
        workers: 4,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec_for("sim")).expect("start");
    let rxs: Vec<_> = stream
        .iter()
        .map(|f| coord.submit(f.clone()).expect("submit"))
        .collect();
    let mut shards = std::collections::BTreeSet::new();
    for rx in rxs {
        shards.insert(rx.recv_timeout(Duration::from_secs(60)).unwrap().shard);
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 256);
    assert!(
        shards.len() >= 2,
        "expected multiple shards to serve the flood, saw {shards:?}"
    );
}
