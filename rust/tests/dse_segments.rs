//! Segment-level DSE invariants (in-tree property harness style):
//!
//! * the per-stage kernel + composition pass reproduce the monolithic
//!   evaluator **bitwise** — same integers in, same integers out — so
//!   every downstream f64 (latency ms, power) and therefore every
//!   Pareto front is unchanged by the stage cache;
//! * the roofline pre-filter's lower bounds are sound (never above the
//!   full evaluator's values), so a prune decision never discards a
//!   candidate the search would have accepted as feasible /
//!   non-dominated at that point;
//! * every engine shortcut — threads, chromosome memo, stage memo,
//!   surrogate ranking — yields a bit-identical front.

use forgemorph::design;
use forgemorph::dse::{self, roofline::GeneBounds, Constraints, DseConfig, DseResult};
use forgemorph::graph::zoo;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::util::rng::Rng;

fn random_genes(bounds: &[usize], rng: &mut Rng) -> Vec<usize> {
    bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect()
}

/// Bitwise identity key of a Pareto front.
fn fingerprint(res: &DseResult) -> Vec<(Vec<usize>, u64, usize)> {
    res.pareto
        .iter()
        .map(|c| {
            (c.config.parallelism.clone(), c.objectives.latency_ms.to_bits(), c.objectives.dsp)
        })
        .collect()
}

#[test]
fn composed_fitness_bitwise_equals_monolithic() {
    // (a) segment composition vs the retained monolithic reference,
    // random genes/reps, branchy plans (yolov5l, unet_tiny) included.
    // FastEval equality is integer-exact, which forces bit-equality of
    // every f64 derived from it downstream.
    let mut rng = Rng::new(71);
    for net in [
        zoo::mnist(),
        zoo::svhn(),
        zoo::cifar10(),
        zoo::mobilenet_v2(),
        zoo::unet_tiny(),
        zoo::yolov5l(),
    ] {
        let ev = design::Evaluator::new(&net, &ZYNQ_7100).unwrap();
        let bounds = net.conv_filter_bounds();
        let iters = if bounds.len() > 60 { 5 } else { 20 };
        for _ in 0..iters {
            let genes = random_genes(&bounds, &mut rng);
            let rep = if rng.chance(0.5) { FpRep::Int8 } else { FpRep::Int16 };
            let mono = ev.objectives(&genes, rep).unwrap();
            let composed = ev.compose(
                (0..ev.n_stages()).map(|s| ev.stage_fit_packed(ev.stage_key(s, &genes), rep)),
            );
            assert_eq!(composed, mono, "{} {:?} {:?}", net.name, genes, rep);
        }
    }
}

#[test]
fn roofline_bounds_are_sound() {
    // (b) the pre-filter's lower bounds never sit above the truth, so
    // "lb violates a cap" implies "the candidate violates the cap"
    let mut rng = Rng::new(72);
    for net in [zoo::mnist(), zoo::mobilenet_v2(), zoo::unet_tiny(), zoo::yolov5l()] {
        let ev = design::Evaluator::new(&net, &ZYNQ_7100).unwrap();
        let bounds = net.conv_filter_bounds();
        let iters = if bounds.len() > 60 { 5 } else { 20 };
        for rep in [FpRep::Int16, FpRep::Int8] {
            let gb = GeneBounds::new(&ev, rep);
            for _ in 0..iters {
                let genes = random_genes(&bounds, &mut rng);
                let fast = ev.objectives(&genes, rep).unwrap();
                assert!(
                    gb.latency_cycles_lb(&genes) <= fast.latency_cycles,
                    "{} {:?}: latency bound above truth",
                    net.name,
                    rep
                );
                assert!(
                    gb.latency_ms_lb(&genes) <= ev.latency_ms(&fast) + 1e-12,
                    "{} {:?}: ms bound above truth",
                    net.name,
                    rep
                );
                assert!(
                    gb.dsp_lb(&genes) <= fast.resources.dsp,
                    "{} {:?}: dsp bound above truth",
                    net.name,
                    rep
                );
            }
        }
    }
}

#[test]
fn dominance_on_bounds_implies_dominance_on_truth() {
    // (b) continued — the prune predicate's dominance rule: a front
    // point that strictly dominates the (lat_lb, dsp_lb) bound point
    // must dominate the fully evaluated candidate too (accuracy is
    // exact, so a 2-objective check suffices)
    let mut rng = Rng::new(73);
    let net = zoo::unet_tiny();
    let ev = design::Evaluator::new(&net, &ZYNQ_7100).unwrap();
    let bounds = net.conv_filter_bounds();
    let gb = GeneBounds::new(&ev, FpRep::Int16);
    let mut dominated_bounds = 0usize;
    for _ in 0..60 {
        let genes = random_genes(&bounds, &mut rng);
        let fast = ev.objectives(&genes, FpRep::Int16).unwrap();
        let (lat_lb, dsp_lb) = (gb.latency_ms_lb(&genes), gb.dsp_lb(&genes));
        let (lat, dsp) = (ev.latency_ms(&fast), fast.resources.dsp);
        // synthetic front point in the neighbourhood of the bound
        let f_lat = lat_lb * (0.5 + rng.f64());
        let f_dsp = ((dsp_lb as f64) * (0.5 + rng.f64())) as usize;
        let dominates_lb = f_lat <= lat_lb
            && f_dsp <= dsp_lb
            && (f_lat < lat_lb || f_dsp < dsp_lb);
        if dominates_lb {
            dominated_bounds += 1;
            assert!(
                f_lat <= lat && f_dsp <= dsp && (f_lat < lat || f_dsp < dsp),
                "front ({f_lat},{f_dsp}) dominated the bound but not the truth ({lat},{dsp})"
            );
        }
    }
    assert!(dominated_bounds > 0, "property never exercised the dominance branch");
}

#[test]
fn fronts_bit_identical_across_engine_shortcuts() {
    // (c) the full flag matrix against the chromosome-memo-only serial
    // baseline (the pre-segment-cache engine): threads x stage memo x
    // surrogate, plus the fully uncached engine
    for net in [zoo::mnist(), zoo::unet_tiny()] {
        let mk = |threads: usize, memo: bool, stage_memo: bool, surrogate: bool| DseConfig {
            population: 24,
            generations: 6,
            seed: 9,
            threads,
            memo,
            stage_memo,
            surrogate,
            constraints: Constraints::device(&ZYNQ_7100),
            ..DseConfig::default()
        };
        let base = dse::run(&net, &ZYNQ_7100, &mk(1, true, false, false));
        let fp = fingerprint(&base);
        assert!(!fp.is_empty(), "{}: empty baseline front", net.name);
        for threads in [1usize, 4] {
            for stage_memo in [false, true] {
                for surrogate in [false, true] {
                    let r = dse::run(&net, &ZYNQ_7100, &mk(threads, true, stage_memo, surrogate));
                    let tag = format!(
                        "{} threads={threads} stage_memo={stage_memo} surrogate={surrogate}",
                        net.name
                    );
                    assert_eq!(fp, fingerprint(&r), "{tag}");
                    assert_eq!(base.evaluated, r.evaluated, "{tag}");
                    assert_eq!(base.best_latency_per_gen, r.best_latency_per_gen, "{tag}");
                    assert_eq!(base.evaluations, r.evaluations, "{tag}");
                    assert_eq!(base.unique_evaluations, r.unique_evaluations, "{tag}");
                }
            }
        }
        let nomemo = dse::run(&net, &ZYNQ_7100, &mk(1, false, true, false));
        assert_eq!(fp, fingerprint(&nomemo), "{}: uncached engine diverged", net.name);
    }
}
