//! Integration: the closed-loop power-aware runtime.
//!
//! Pins the PR's core guarantees:
//! * **determinism** — a budget-trace replay produces a byte-identical
//!   decision log, identical per-path frame counts and (up to f64
//!   rounding) identical energy on 1 worker and 4 workers, and for any
//!   frame seed;
//! * **the paper's claim** — the step squeeze cuts modeled power by
//!   >= 30% on the Table III-class MNIST mapping (Figs. 11-12);
//! * **floor safety** — a below-floor path is never pinned, even when
//!   only it satisfies the budget (the governor soft-overruns instead);
//! * **no loss** — every in-flight request is answered across
//!   drain→swap→resume reconfigurations.

use std::collections::BTreeMap;

use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{trace, Coordinator, ServeConfig, TraceConfig, TraceOutcome};
use forgemorph::design::DesignConfig;
use forgemorph::graph::zoo;
use forgemorph::morph;
use forgemorph::pe::{FpRep, ZYNQ_7100};

const FRAMES: usize = 240;
const RATE_HZ: f64 = 4000.0;

fn start(workers: usize, accuracy_floor: f64, backend: &str) -> Coordinator {
    let net = zoo::mnist();
    // Table III 164-PE-class mapping: gated blocks dominate the draw
    let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
    let paths = morph::depth_ladder(&net);
    let spec = match backend {
        "sim" => BackendSpec::sim(net, design, ZYNQ_7100, paths),
        "analytical" => BackendSpec::analytical(net, design, ZYNQ_7100, paths),
        other => panic!("unknown backend {other}"),
    };
    let cfg = ServeConfig {
        workers,
        accuracy_floor,
        external_pacing: true,
        ..ServeConfig::default()
    };
    Coordinator::start(cfg, spec).expect("start")
}

/// Step-trace replay with the canonical cap just above the lightest path.
fn replay(workers: usize, seed: u64, accuracy_floor: f64, backend: &str) -> TraceOutcome {
    let mut coord = start(workers, accuracy_floor, backend);
    let cap = trace::default_squeeze_cap(&coord.path_energy_rows());
    let events = trace::step(FRAMES as f64 / RATE_HZ, cap);
    coord
        .replay_power_trace(&events, &TraceConfig { frames: FRAMES, rate_hz: RATE_HZ, seed })
        .expect("replay")
}

#[test]
fn decision_log_identical_across_worker_counts_and_seeds() {
    let reference = replay(1, 42, 0.0, "sim");
    assert!(!reference.decision_log().is_empty(), "no switches recorded");
    for (workers, seed) in [(4usize, 42u64), (1, 99), (4, 7)] {
        let got = replay(workers, seed, 0.0, "sim");
        assert_eq!(
            reference.decision_log(),
            got.decision_log(),
            "decision log diverged at workers={workers} seed={seed}"
        );
        assert_eq!(
            reference.frames_by_path, got.frames_by_path,
            "frame accounting diverged at workers={workers} seed={seed}"
        );
        assert_eq!(reference.switches, got.switches);
        // energy integrals agree up to summation-order rounding
        let rel = (reference.energy_mj - got.energy_mj).abs() / reference.energy_mj;
        assert!(rel < 1e-9, "energy diverged by {rel}");
        let m_rel = (reference.metrics.energy_j - got.metrics.energy_j).abs()
            / reference.metrics.energy_j;
        assert!(m_rel < 1e-9, "shard-merged energy diverged by {m_rel}");
    }
}

#[test]
fn step_squeeze_cuts_power_at_least_thirty_pct() {
    let out = replay(4, 42, 0.0, "sim");
    // down-shift fired off the full path, release upshifted back
    assert!(out.switches.len() >= 2, "{:?}", out.switches);
    assert_eq!(out.switches[0].from, "d3_w100");
    assert_ne!(out.switches[0].to, "d3_w100");
    assert_eq!(out.switches[0].stall_frames, 0, "down-shift must be free");
    let back = out.switches.last().unwrap();
    assert_eq!(back.to, "d3_w100");
    assert_eq!(back.stall_frames, 1, "up-shift pays the reactivation stall");
    assert!(back.swap_ms > 0.0, "up-shift swap window must be modeled");
    let reduction = out.squeeze_reduction_pct().expect("squeeze segment present");
    assert!(
        reduction >= 30.0,
        "squeeze saved only {reduction:.1}% (paper claims up to ~32%)"
    );
    // every frame answered: drain→swap→resume loses nothing
    assert_eq!(out.answered, FRAMES);
    assert_eq!(out.metrics.requests as usize, FRAMES);
    // telemetry consistency: per-path energies sum to the total
    let sum: f64 = out.metrics.energy_mj_by_path.values().sum();
    assert!((sum / 1000.0 - out.metrics.energy_j).abs() < 1e-9);
    assert!(out.metrics.mean_power_mw() > 0.0);
}

#[test]
fn below_floor_paths_never_pinned() {
    // floor 0.95 bans d1_w100 (0.93); the cap only d1 could satisfy must
    // soft-overrun to the cheapest floor-meeting path instead
    let out = replay(4, 42, 0.95, "sim");
    let registry: BTreeMap<&str, f64> =
        [("d1_w100", 0.93), ("d2_w100", 0.96), ("d3_w100", 0.99)].into();
    for (path, frames) in &out.frames_by_path {
        assert!(
            registry[path.as_str()] >= 0.95,
            "below-floor path {path} served {frames} frames"
        );
    }
    assert!(!out.frames_by_path.contains_key("d1_w100"));
    // the squeeze still bites — d2 is cheaper than the full path
    assert_eq!(out.switches[0].to, "d2_w100");
    assert!(out.squeeze_reduction_pct().unwrap() > 0.0);
}

#[test]
fn analytical_backend_replays_deterministically_too() {
    let a = replay(1, 5, 0.0, "analytical");
    let b = replay(4, 5, 0.0, "analytical");
    assert_eq!(a.decision_log(), b.decision_log());
    assert_eq!(a.frames_by_path, b.frames_by_path);
    assert!(!a.switches.is_empty());
    assert_eq!(a.answered, FRAMES);
}

#[test]
fn ramp_trace_steps_down_through_the_ladder() {
    // a ramp through both intermediate caps must visit an intermediate
    // path on its way down (multi-level morphing, not a single jump)
    let mut coord = start(1, 0.0, "sim");
    let rows = coord.path_energy_rows();
    let by_name = |n: &str| rows.iter().find(|e| e.name == n).unwrap().power_mw;
    let (p1, p2, p3) = (by_name("d1_w100"), by_name("d2_w100"), by_name("d3_w100"));
    let mid_cap = (p2 + p3) / 2.0; // admits d2, rejects full
    let low_cap = (p1 + p2) / 2.0; // admits only d1
    let duration = FRAMES as f64 / RATE_HZ;
    let events = trace::ramp(duration, mid_cap, low_cap, 2);
    let out = coord
        .replay_power_trace(&events, &TraceConfig { frames: FRAMES, rate_hz: RATE_HZ, seed: 1 })
        .expect("replay");
    let visited: Vec<&str> = out.switches.iter().map(|s| s.to.as_str()).collect();
    assert!(visited.contains(&"d2_w100"), "skipped the mid path: {visited:?}");
    assert!(visited.contains(&"d1_w100"), "never reached the light path: {visited:?}");
    assert_eq!(out.answered, FRAMES);
}
