//! Integration: the full compile-side pipeline (no artifacts needed) and
//! the serving coordinator (artifacts-gated).
//!
//! descriptor JSON -> graph -> NeuroForge DSE -> RTL emission -> cycle
//! simulation -> NeuroMorph governor, plus an end-to-end coordinator run
//! with a mid-flight budget squeeze.

use std::time::Duration;

use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{sim_path_costs, Coordinator, ServeConfig};
use forgemorph::design::{self, DesignConfig};
use forgemorph::dse;
use forgemorph::graph::{parser, zoo};
use forgemorph::morph::governor::{Budget, Decision, Governor};
use forgemorph::morph::PathRegistry;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::rtl;
use forgemorph::sim::{self, GateMask};
use forgemorph::util::rng::Rng;

const DESCRIPTOR: &str = r#"{
  "name": "custom-6-12",
  "input": [16, 16, 1],
  "layers": [
    {"type": "conv", "filters": 6, "k": 3},
    {"type": "maxpool", "k": 2},
    {"type": "conv", "filters": 12, "k": 3},
    {"type": "maxpool", "k": 2},
    {"type": "fc", "out": 4}
  ]
}"#;

#[test]
fn descriptor_to_rtl_to_sim() {
    // parse
    let net = parser::parse(DESCRIPTOR).expect("parse");
    assert_eq!(net.conv_filter_bounds(), vec![6, 12]);

    // explore
    let cfg = dse::DseConfig {
        population: 24,
        generations: 8,
        seed: 5,
        constraints: dse::Constraints::device(&ZYNQ_7100),
        ..dse::DseConfig::default()
    };
    let res = dse::run(&net, &ZYNQ_7100, &cfg);
    assert!(!res.pareto.is_empty());

    // pick the fastest point, emit RTL, simulate it
    let best = &res.pareto[0];
    let eval = design::evaluate(&net, &best.config, &ZYNQ_7100).unwrap();
    let bundle = rtl::emit(&net, &best.config, &eval);
    assert!(bundle.file("custom_6_12_top.v").is_some());
    let report = sim::simulate(&net, &best.config, &ZYNQ_7100, &GateMask::all_active());
    assert!(report.latency_cycles >= eval.latency_cycles as u64);
}

#[test]
fn governor_tracks_budget_trace() {
    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
    // registry with real sim-derived costs for the three depth paths
    let paths: Vec<forgemorph::morph::MorphPath> = (1..=3)
        .map(|d| forgemorph::morph::MorphPath {
            name: format!("d{d}_w100"),
            depth: d,
            width_pct: 100,
            accuracy: 0.9 + d as f64 * 0.03,
            params: d * 1000,
            macs: d * 100_000,
        })
        .collect();
    let registry = PathRegistry::new(paths);
    let costs = sim_path_costs(&net, &design, &ZYNQ_7100, &registry).expect("lowerable paths");
    let mut gov = Governor::new(registry, costs, 1);
    assert_eq!(gov.current(), "d3_w100");

    // squeeze power below the full path's draw -> governor must downshift
    let full_power = sim::simulate(&net, &design, &ZYNQ_7100, &GateMask::all_active()).power_mw;
    let squeezed = Budget { power_mw: Some(full_power - 40.0), latency_ms: None };
    match gov.observe(&squeezed) {
        Decision::Switch { to, .. } => assert_ne!(to, "d3_w100"),
        Decision::Hold => panic!("governor ignored the power squeeze"),
    }
    // relax -> back to full
    match gov.observe(&Budget::unconstrained()) {
        Decision::Switch { to, stall_frames } => {
            assert_eq!(to, "d3_w100");
            assert_eq!(stall_frames, 1);
        }
        Decision::Hold => panic!("governor failed to upshift"),
    }
}

#[test]
fn coordinator_serves_and_morphs() {
    // the sim backend needs no AOT artifacts: the full serving stack
    // (sharded coordinator, batcher, shared governor, metrics merge)
    // runs self-contained in tier-1
    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
    let spec = BackendSpec::sim(
        net.clone(),
        design.clone(),
        ZYNQ_7100,
        forgemorph::morph::depth_ladder(&net),
    );
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        patience: 1,
        workers: 2,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec).expect("coordinator start");

    let mut rng = Rng::new(7);
    let mut paths_seen = std::collections::BTreeSet::new();
    let mut answered = 0;
    let mut run_phase = |coord: &mut Coordinator,
                         paths_seen: &mut std::collections::BTreeSet<String>,
                         answered: &mut usize| {
        let mut rxs = Vec::new();
        for _ in 0..24 {
            let frame: Vec<f32> = (0..784).map(|_| rng.f64() as f32).collect();
            rxs.push(coord.submit(frame).expect("submit"));
        }
        // drain this phase's responses before changing the budget, so the
        // governor decision is observable per phase
        for rx in rxs {
            if let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
                assert_eq!(resp.logits.len(), 10);
                assert!(resp.class < 10);
                paths_seen.insert(resp.path);
                *answered += 1;
            }
        }
    };

    // phase 1: unconstrained -> full path
    run_phase(&mut coord, &mut paths_seen, &mut answered);
    // phase 2: power squeeze -> cheaper path
    let full_power = sim::simulate(&net, &design, &ZYNQ_7100, &GateMask::all_active()).power_mw;
    coord
        .set_budget(Budget { power_mw: Some(full_power - 40.0), latency_ms: None })
        .expect("set_budget");
    run_phase(&mut coord, &mut paths_seen, &mut answered);
    let metrics = coord.shutdown();
    assert_eq!(answered, 48, "all requests answered");
    assert_eq!(metrics.requests, 48);
    assert!(
        paths_seen.len() >= 2,
        "budget squeeze should trigger a morph (saw {paths_seen:?})"
    );
    assert!(metrics.morph_switches >= 1);
    assert!(metrics.energy_j > 0.0);
}

#[test]
fn report_harness_produces_all_blocks() {
    for id in ["table1", "table2", "fig8"] {
        let text = forgemorph::report::by_name(id).unwrap();
        assert!(text.len() > 100, "{id} too small");
    }
}
