//! Integration: AOT artifacts -> PJRT engine -> numerics.
//!
//! Requires `make artifacts` (skips gracefully otherwise). This is the
//! end-to-end proof that the three layers compose: DistillCycle-trained
//! Pallas kernels, lowered to HLO text by `aot.py`, loaded and executed
//! by the Rust runtime with NO Python anywhere in this process.

use std::path::PathBuf;

use forgemorph::runtime::{Engine, Manifest};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<Engine> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load(&artifacts_dir(), "mnist").expect("engine load"))
}

#[test]
fn loads_all_morph_paths() {
    let Some(engine) = engine() else { return };
    let model = engine.model();
    assert_eq!(model.input_shape, (28, 28, 1));
    assert_eq!(model.num_classes, 10);
    let names: Vec<&str> = model.paths.iter().map(|p| p.path.name.as_str()).collect();
    assert_eq!(names, vec!["d1_w100", "d2_w100", "d3_w100", "d3_w50"]);
    for p in &model.paths {
        let mut batches = engine.batches_for(&p.path.name);
        batches.sort_unstable();
        assert_eq!(batches, model.batches, "path {}", p.path.name);
    }
}

#[test]
fn probe_logits_match_golden() {
    // The core numerics check: Rust/PJRT executes the Pallas-lowered HLO
    // and reproduces the logits Python recorded at AOT time.
    let Some(engine) = engine() else { return };
    let errs = engine.verify_probe().expect("probe execution");
    for (path, err) in errs {
        assert!(err < 1e-3, "path {path}: max|err| = {err}");
    }
}

#[test]
fn batch1_and_batch8_agree() {
    let Some(engine) = engine() else { return };
    let frame = engine.frame_len();
    let probe = &engine.model().probe;
    let batch = probe.shape[0].min(8);
    let logits8 = engine.execute("d3_w100", batch, &probe.x[..batch * frame]).unwrap();
    for i in 0..batch {
        let logits1 = engine
            .execute("d3_w100", 1, &probe.x[i * frame..(i + 1) * frame])
            .unwrap();
        for (a, b) in logits1.iter().zip(&logits8[i * 10..(i + 1) * 10]) {
            assert!((a - b).abs() < 1e-4, "frame {i}: {a} vs {b}");
        }
    }
}

#[test]
fn paths_disagree_on_logits() {
    // different morph paths are different functions — gating is real
    let Some(engine) = engine() else { return };
    let frame = engine.frame_len();
    let probe = &engine.model().probe;
    let a = engine.execute("d1_w100", 1, &probe.x[..frame]).unwrap();
    let b = engine.execute("d3_w100", 1, &probe.x[..frame]).unwrap();
    let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-3, "paths produced identical logits");
}

#[test]
fn quantized_artifact_loads_and_runs() {
    let Some(_engine) = engine() else { return };
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    let model = manifest.model("mnist").unwrap();
    let Some(file) = model.quant_full.get(&8) else {
        panic!("int8 artifact missing from manifest");
    };
    // compile + run the int8-emulated full path directly
    let client = xla::PjRtClient::cpu().unwrap();
    let proto =
        xla::HloModuleProto::from_text_file(manifest.file_path(file).to_str().unwrap()).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let frame = 28 * 28;
    let x = xla::Literal::vec1(&model.probe.x[..frame])
        .reshape(&[1, 28, 28, 1])
        .unwrap();
    let out = exe.execute::<xla::Literal>(&[x]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap()
        .to_vec::<f32>()
        .unwrap();
    assert_eq!(out.len(), 10);
    // int8 datapath must stay close to the f32 golden logits
    let want = &model.probe.logits["d3_w100"][..10];
    let err = out
        .iter()
        .zip(want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1.0, "int8 deviation too large: {err}");
}

#[test]
fn bad_requests_rejected() {
    let Some(engine) = engine() else { return };
    assert!(engine.execute("d3_w100", 3, &vec![0.0; 3 * 784]).is_err());
    assert!(engine.execute("nope", 1, &vec![0.0; 784]).is_err());
    assert!(engine.execute("d3_w100", 1, &vec![0.0; 7]).is_err());
}

#[test]
fn argmax_sane() {
    let Some(engine) = engine() else { return };
    let v = vec![0.0, 1.0, 0.5, 9.0, -2.0, 0.0, 0.0, 0.0, 0.0, 0.0];
    assert_eq!(engine.argmax(&v), vec![3]);
}
