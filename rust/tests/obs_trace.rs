//! Integration: deterministic tracing & telemetry layer (`obs`).
//!
//! Pins the PR's core guarantees:
//! * **deterministic export** — the Chrome trace exported with the
//!   deterministic flag is byte-identical on 1 and 4 workers and across
//!   reruns, for the full fault storm over a step power trace, and it
//!   contains every required span family with zero dropped spans;
//! * **no observer effect** — attaching a sink changes no output byte:
//!   decision log, fault log and summary match the untraced run
//!   bit-for-bit;
//! * **span well-formedness** — virtual execute spans all carry an
//!   interned path and cover every frame, drained entries arrive
//!   sorted, and retry instants never precede their transient parent.

use std::sync::Arc;

use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{trace, Coordinator, ServeConfig, TraceConfig, TraceOutcome};
use forgemorph::design::DesignConfig;
use forgemorph::fault::FaultPlan;
use forgemorph::graph::zoo;
use forgemorph::morph;
use forgemorph::obs::{export, Clock, Kind, Name, TraceSink};
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::util::json::Json;

const FRAMES: usize = 240;
const RATE_HZ: f64 = 4000.0;
const SEED: u64 = 7;

fn start(workers: usize, sink: Option<Arc<TraceSink>>) -> Coordinator {
    let net = zoo::mnist();
    // same Table III-class mapping as the power/fault-loop tests
    let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
    let paths = morph::depth_ladder(&net);
    let spec = BackendSpec::sim(net, design, ZYNQ_7100, paths);
    let cfg = ServeConfig {
        workers,
        external_pacing: true,
        trace: sink,
        ..ServeConfig::default()
    };
    Coordinator::start(cfg, spec).expect("start")
}

/// Step-trace replay, optionally under the canonical fault storm.
fn replay(workers: usize, sink: Option<Arc<TraceSink>>, storm: bool) -> TraceOutcome {
    let mut coord = start(workers, sink);
    let cap = trace::default_squeeze_cap(&coord.path_energy_rows());
    let events = trace::step(FRAMES as f64 / RATE_HZ, cap);
    let plan = storm.then(|| {
        FaultPlan::parse_spec(FaultPlan::storm_spec(), FRAMES, RATE_HZ, SEED)
            .expect("fault spec")
    });
    coord
        .replay_trace(
            &events,
            &TraceConfig { frames: FRAMES, rate_hz: RATE_HZ, seed: SEED },
            plan.as_ref(),
        )
        .expect("replay")
}

/// The deterministic Chrome export of one storm+power replay.
fn storm_chrome(workers: usize) -> String {
    let sink = TraceSink::shared();
    replay(workers, Some(sink.clone()), true);
    export::chrome_trace(&sink.drain(), true)
}

#[test]
fn deterministic_export_is_byte_identical_across_workers_and_reruns() {
    let w1 = storm_chrome(1);
    let w4 = storm_chrome(4);
    let again = storm_chrome(4);
    assert_eq!(w1, w4, "worker count leaked into the deterministic trace");
    assert_eq!(w4, again, "rerun changed the deterministic trace");
    // required span families, greppable exactly the way CI greps them
    for marker in ["\"switch\"", "\"swap_window\"", "\"retry\"", "\"scrub_repair\""] {
        assert!(w1.contains(marker), "{marker} missing from storm trace");
    }
    let parsed = Json::parse(&w1).expect("exporter emits valid JSON");
    let other = parsed.get("otherData").expect("otherData present");
    assert_eq!(other.get("dropped").and_then(Json::as_u64), Some(0));
    assert_eq!(other.get("deterministic").and_then(Json::as_bool), Some(true));
    let events = parsed.get("traceEvents").and_then(Json::as_arr).expect("events");
    assert!(events.len() > 2 * FRAMES, "storm trace suspiciously small");
    // the deterministic view quarantines every wall-clock entry
    assert!(!w1.contains("\"wall\""));
}

#[test]
fn attaching_a_sink_changes_no_output_byte() {
    let bare = replay(4, None, true);
    let sink = TraceSink::shared();
    let traced = replay(4, Some(sink.clone()), true);
    assert!(!sink.is_empty(), "sink attached but nothing recorded");
    // the bit-for-bit acceptance contract: tracing off the hot path
    // never perturbs what the untraced engine prints
    assert_eq!(bare.decision_log(), traced.decision_log());
    assert_eq!(bare.fault_log(), traced.fault_log());
    assert_eq!(bare.render_summary(), traced.render_summary());
}

#[test]
fn virtual_spans_are_well_formed() {
    for storm in [false, true] {
        let sink = TraceSink::shared();
        replay(2, Some(sink.clone()), storm);
        let dump = sink.drain();
        assert_eq!(dump.dropped, 0, "storm={storm}: ring overflowed");
        // drained entries arrive sorted (the ring merge is a sorted union)
        assert!(
            dump.entries.windows(2).all(|w| w[0] <= w[1]),
            "storm={storm}: drained entries out of order"
        );
        let virt: Vec<_> = dump.entries.iter().filter(|e| e.clock == Clock::Virtual).collect();
        let enqueues = virt.iter().filter(|e| e.name == Name::Enqueue).count();
        let executes: Vec<_> = virt
            .iter()
            .filter(|e| e.name == Name::Execute && e.kind == Kind::Span)
            .collect();
        assert_eq!(enqueues, FRAMES, "storm={storm}: one virtual enqueue per frame");
        assert_eq!(executes.len(), FRAMES, "storm={storm}: one execute span per frame");
        assert!(
            executes.iter().all(|e| e.path != 0),
            "storm={storm}: execute span without an interned path"
        );
        assert!(
            executes.iter().all(|e| dump.path_name(e.path).is_some()),
            "storm={storm}: execute span path not resolvable"
        );
        // retry instants ride at or after their transient parent, with
        // 1-based attempt numbers
        let mut parents: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
        for e in virt.iter().filter(|e| e.name == Name::FaultTransient) {
            let slot = parents.entry(e.id).or_insert(e.ts_us);
            *slot = (*slot).min(e.ts_us);
        }
        let retries: Vec<_> = virt.iter().filter(|e| e.name == Name::Retry).collect();
        if storm {
            assert!(!retries.is_empty(), "storm produced no retry instants");
        } else {
            assert!(retries.is_empty(), "fault-free replay produced retries");
        }
        for r in &retries {
            let base = parents.get(&r.id).expect("retry without a transient parent");
            assert!(r.ts_us >= *base, "retry precedes its transient: {r:?}");
            assert!(r.a0 >= 1, "attempt numbers are 1-based: {r:?}");
        }
    }
}

#[test]
fn folded_and_snapshot_exports_cover_the_storm() {
    let sink = TraceSink::shared();
    replay(1, Some(sink.clone()), true);
    let dump = sink.drain();
    let folded = export::folded(&dump, true);
    assert!(folded.contains("request;execute;"), "{folded}");
    // folded lines are "stack total_us" pairs, aggregated and sorted
    let mut keys = Vec::new();
    for line in folded.lines() {
        let (key, us) = line.rsplit_once(' ').expect("stack + total");
        us.parse::<u64>().expect("total is integral microseconds");
        keys.push(key.to_string());
    }
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "folded stacks must come out sorted");
    let snap = export::text_snapshot(&dump);
    assert!(snap.contains("dropped"), "{snap}");
    assert!(snap.contains("fault;"), "{snap}");
}
