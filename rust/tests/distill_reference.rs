//! DistillCycle reference pinning + end-to-end integration.
//!
//! Part 1 mirrors `python/tests/test_distill.py` assertion-for-assertion
//! on the Rust engine (same training *dynamics*, same exact reference
//! vectors where the Python suite pins them — the Eq. 20 LR tree, the
//! KD/CE loss identities, phase ordering, per-path history coverage).
//! The suites share budgets small enough for debug-build CI.
//!
//! Part 2 pins the integration contract of ISSUE 4: the profile JSON is
//! byte-identical across reruns, `explore` consumes it as a third
//! NSGA-II objective, and the governor enforces the profile floor.

use forgemorph::distill::{
    self, AccuracyProfile, DistillConfig, DistillSpec, Phase,
};
use forgemorph::dse;
use forgemorph::graph::zoo;
use forgemorph::morph::governor::{Budget, Governor, PathCosts};
use forgemorph::morph::PathRegistry;
use forgemorph::pe::ZYNQ_7100;

/// The shared trained fixture (the `_trained()` lru_cache of the Python
/// suite): the tiny 3-block ladder, trained once per process.
fn trained() -> &'static (DistillSpec, distill::TrainResult) {
    use std::sync::OnceLock;
    static TRAINED: OnceLock<(DistillSpec, distill::TrainResult)> = OnceLock::new();
    TRAINED.get_or_init(|| {
        let spec = DistillSpec::tiny();
        let ds = spec.dataset(384, 128, 0);
        let cfg = DistillConfig { epochs_per_stage: 2, batch: 32, ..DistillConfig::default() };
        let res = distill::distillcycle_train(&spec, &ds, &cfg);
        (spec, res)
    })
}

// ---------------------------------------------------------------------------
// Part 1 — python/tests/test_distill.py mirrored on the Rust engine
// ---------------------------------------------------------------------------

/// `test_losses_decrease_within_teacher_phase`
#[test]
fn losses_decrease_within_teacher_phase() {
    let (_, res) = trained();
    let teacher_stage1: Vec<f64> = res
        .history
        .iter()
        .filter(|h| h.stage == 1 && h.phase == Phase::Teacher && h.path == "d1_w100")
        .map(|h| h.loss)
        .collect();
    assert!(teacher_stage1.len() >= 2);
    assert!(
        teacher_stage1.last().unwrap() < teacher_stage1.first().unwrap(),
        "{teacher_stage1:?}"
    );
}

/// `test_all_paths_beat_chance` (4 classes here: chance = 0.25), with a
/// stronger bar for the full-width paths the teacher phases train
/// directly.
#[test]
fn all_paths_beat_chance() {
    let (spec, res) = trained();
    assert_eq!(res.accuracies.len(), spec.paths().len());
    for (name, acc) in &res.accuracies {
        assert!(*acc > 0.30, "{name}: {acc} vs chance 0.25");
        if name.ends_with("w100") {
            assert!(*acc > 0.50, "full-width {name}: {acc}");
        }
    }
}

/// `test_every_path_has_history`
#[test]
fn every_path_has_history() {
    let (_, res) = trained();
    let trained_names: std::collections::BTreeSet<&str> =
        res.history.iter().map(|h| h.path.as_str()).collect();
    for p in ["d1_w100", "d2_w100", "d3_w100", "d3_w50"] {
        assert!(trained_names.contains(p), "{p} never trained: {trained_names:?}");
    }
}

/// `test_polish_phase_runs_last` — the last *trunk-training* phase is
/// the full-path polish (the Rust engine then appends head-only
/// calibration records, a deliberate extension over train.py: trunk
/// weights are frozen there, so polish remains the final trunk update).
#[test]
fn polish_phase_runs_last() {
    let (_, res) = trained();
    let last_trunk = res
        .history
        .iter()
        .filter(|h| h.phase != Phase::Calibrate)
        .next_back()
        .unwrap();
    assert_eq!(last_trunk.phase, Phase::Polish);
    assert_eq!(last_trunk.path, "d3_w100");
    // calibration covers every non-full path, after polish
    let cal: Vec<&str> = res
        .history
        .iter()
        .filter(|h| h.phase == Phase::Calibrate)
        .map(|h| h.path.as_str())
        .collect();
    assert_eq!(cal, vec!["d1_w100", "d1_w50", "d2_w100", "d2_w50", "d3_w50"]);
}

/// `test_kd_loss_zero_when_matching`
#[test]
fn kd_loss_zero_when_matching() {
    let logits: Vec<f32> = (0..40).map(|i| ((i * 31 % 17) as f32 - 8.0) * 0.3).collect();
    assert!(distill::kd_loss(&logits, &logits, 10, 3.0) < 1e-5);
}

/// `test_kd_loss_positive_when_differing`
#[test]
fn kd_loss_positive_when_differing() {
    let a: Vec<f32> = (0..40).map(|i| ((i * 31 % 17) as f32 - 8.0) * 0.3).collect();
    let b: Vec<f32> = (0..40).map(|i| ((i * 13 % 19) as f32 - 9.0) * 0.3).collect();
    assert!(distill::kd_loss(&a, &b, 10, 3.0) > 0.0);
}

/// `test_cross_entropy_perfect_prediction`
#[test]
fn cross_entropy_perfect_prediction() {
    let logits = vec![10.0f32, -10.0, -10.0, 10.0];
    let y = vec![0u32, 1];
    assert!(distill::cross_entropy(&logits, 2, &y) < 1e-3);
}

/// `test_lr_tree_decays_early_blocks` — the exact Eq. 20 reference
/// vector `[γ², γ¹, γ⁰]·α = [0.025, 0.05, 0.1]`.
#[test]
fn lr_tree_decays_early_blocks() {
    let spec = DistillSpec::tiny();
    let tree = distill::lr_tree(&spec, 3, 0.1, 0.5, 0.1);
    assert_eq!(tree.blocks, vec![0.025, 0.05, 0.1]);
    assert_eq!(tree.head, 0.1);
}

/// `test_lr_tree_head_override`
#[test]
fn lr_tree_head_override() {
    let spec = DistillSpec::tiny();
    let tree = distill::lr_tree(&spec, 2, 0.01, 0.5, 0.3);
    assert_eq!(tree.head, 0.3);
}

// ---------------------------------------------------------------------------
// Part 2 — ISSUE 4 acceptance: profile -> DSE -> governor
// ---------------------------------------------------------------------------

/// Golden-value determinism: same seed -> byte-identical profile JSON,
/// independent of how many threads anything else uses. (The engine is
/// single-threaded by construction; this pins the whole pipeline —
/// dataset, init, training order, JSON encoding.)
#[test]
fn profile_json_byte_identical_across_reruns() {
    let spec = DistillSpec::tiny();
    let cfg = DistillConfig { epochs_per_stage: 1, batch: 32, ..DistillConfig::default() };
    let a = distill::train_profile(&spec, &spec.dataset(128, 64, 3), &cfg).to_json();
    let b = distill::train_profile(&spec, &spec.dataset(128, 64, 3), &cfg).to_json();
    assert_eq!(a, b, "profile JSON must be byte-identical for one seed");
    // and a different seed really changes it
    let c = distill::train_profile(
        &spec,
        &spec.dataset(128, 64, 4),
        &DistillConfig { seed: 4, ..cfg },
    )
    .to_json();
    assert_ne!(a, c);
}

/// `explore` 3-objective fronts take their accuracy values verbatim from
/// the DistillCycle profile, bit-identically across thread counts.
#[test]
fn dse_three_objective_front_uses_profile_accuracies() {
    let spec = DistillSpec::from_network(&zoo::mnist()).unwrap();
    // budget-friendly stand-in profile: same ladder geometry, accuracies
    // stamped without a full mnist training run
    let mut profile = {
        let tiny = DistillSpec::tiny();
        let cfg = DistillConfig { epochs_per_stage: 1, batch: 32, ..DistillConfig::default() };
        distill::train_profile(&tiny, &tiny.dataset(128, 64, 0), &cfg)
    };
    // re-key the ladder onto the mnist geometry (same path names)
    for (p, spec_path) in profile.paths.iter_mut().zip(spec.paths()) {
        p.params = spec.count_params(spec_path);
        p.macs = spec.count_macs(spec_path);
    }
    let profile = AccuracyProfile::parse(&profile.to_json()).unwrap();
    let ladder = profile.morph_paths();
    let ladder_accs: Vec<f64> = ladder.iter().map(|p| p.accuracy).collect();

    let net = zoo::mnist();
    let mk = |threads: usize| dse::DseConfig {
        population: 24,
        generations: 8,
        seed: 11,
        threads,
        accuracy_paths: Some(ladder.clone()),
        constraints: dse::Constraints::device(&ZYNQ_7100),
        ..dse::DseConfig::default()
    };
    let serial = dse::run(&net, &ZYNQ_7100, &mk(1));
    let parallel = dse::run(&net, &ZYNQ_7100, &mk(4));
    assert!(!serial.pareto.is_empty());
    for c in &serial.pareto {
        assert!(
            ladder_accs.iter().any(|&a| a == c.objectives.accuracy),
            "front accuracy {} not from the profile",
            c.objectives.accuracy
        );
    }
    let key = |r: &dse::DseResult| -> Vec<(Vec<usize>, u64, usize, u64)> {
        r.pareto
            .iter()
            .map(|c| {
                (
                    c.config.parallelism.clone(),
                    c.objectives.latency_ms.to_bits(),
                    c.objectives.dsp,
                    c.objectives.accuracy.to_bits(),
                )
            })
            .collect()
    };
    assert_eq!(key(&serial), key(&parallel), "thread count changed the 3-D front");
}

/// The governor consumes the profile floor: under any budget squeeze it
/// never selects a path whose trained accuracy is below the floor.
#[test]
fn governor_enforces_profile_floor_end_to_end() {
    let (_, res) = trained();
    let spec = DistillSpec::tiny();
    let cfg = DistillConfig { epochs_per_stage: 2, batch: 32, ..DistillConfig::default() };
    let profile = AccuracyProfile::from_result(&spec, &cfg, res);
    // the strictest satisfiable floor: only best-accuracy paths remain
    // deployable, so every weaker path is banned even where it wins on
    // power/latency — and the floor stays exactly attainable (the
    // boundary case: a path AT the floor is legal)
    let floor = profile.paths.iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max);
    let paths = profile.morph_paths();
    let full_macs = paths.iter().map(|p| p.macs).max().unwrap() as f64;
    let costs = PathCosts {
        rows: paths
            .iter()
            .map(|p| {
                let r = p.macs as f64 / full_macs;
                (p.name.clone(), 455.0 + 300.0 * r, 1.2 * r)
            })
            .collect(),
    };
    let mut gov =
        Governor::new(PathRegistry::new(paths), costs, 1).with_accuracy_floor(floor);
    let squeezes = [
        Budget::unconstrained(),
        Budget { power_mw: Some(500.0), latency_ms: None },
        Budget { power_mw: Some(1.0), latency_ms: Some(0.0001) },
        Budget { power_mw: None, latency_ms: Some(0.4) },
    ];
    for b in &squeezes {
        gov.observe(b);
        let cur = gov.registry().by_name(gov.current()).unwrap();
        assert!(
            cur.accuracy >= floor,
            "budget {b:?}: selected '{}' ({}) below floor {floor}",
            cur.name,
            cur.accuracy
        );
    }
}

/// Profile accuracies persist into the runtime manifest and replace the
/// explicit-null (untrained) markers.
#[test]
fn profile_persists_into_manifest() {
    let spec = DistillSpec::tiny();
    let cfg = DistillConfig { epochs_per_stage: 1, batch: 32, ..DistillConfig::default() };
    let profile = distill::train_profile(&spec, &spec.dataset(128, 64, 0), &cfg);
    let manifest_text = r#"{
      "version": 1,
      "models": {
        "tiny3": {
          "input_shape": [12, 12, 1],
          "num_classes": 4,
          "filters": [4, 6, 8],
          "batches": [1],
          "paths": [
            {"name": "d1_w100", "depth": 1, "width_pct": 100, "accuracy": null,
             "artifacts": {"1": "a.hlo.txt"}},
            {"name": "d1_w50", "depth": 1, "width_pct": 50, "accuracy": null,
             "artifacts": {"1": "b.hlo.txt"}},
            {"name": "d2_w100", "depth": 2, "width_pct": 100, "accuracy": null,
             "artifacts": {"1": "c.hlo.txt"}},
            {"name": "d2_w50", "depth": 2, "width_pct": 50, "accuracy": null,
             "artifacts": {"1": "d.hlo.txt"}},
            {"name": "d3_w100", "depth": 3, "width_pct": 100, "accuracy": null,
             "artifacts": {"1": "e.hlo.txt"}},
            {"name": "d3_w50", "depth": 3, "width_pct": 50, "accuracy": null,
             "artifacts": {"1": "f.hlo.txt"}}
          ],
          "probe": {"shape": [1, 2], "x": [0.0, 1.0], "logits": {}}
        }
      }
    }"#;
    let mut manifest =
        forgemorph::runtime::Manifest::parse(std::path::Path::new("/tmp"), manifest_text)
            .unwrap();
    let model = manifest.models.get_mut("tiny3").unwrap();
    // untrained markers parse as 0.0 ...
    assert!(model.paths.iter().all(|p| p.path.accuracy == 0.0));
    // ... and the profile replaces them with trained values
    assert_eq!(profile.apply_to(model).unwrap(), 6);
    for p in &model.paths {
        let trained = profile.paths.iter().find(|q| q.name == p.path.name).unwrap();
        assert_eq!(p.path.accuracy, trained.accuracy);
        assert!(p.path.accuracy > 0.0);
    }
}
