//! Integration: deterministic fault injection + self-healing.
//!
//! Pins the PR's core guarantees:
//! * **zero loss** — under every built-in fault trace (and the full
//!   storm) each submitted frame resolves terminally:
//!   `ok + degraded + failed == answered == submitted`;
//! * **log determinism** — the fault log AND the decision log are
//!   byte-identical on 1 worker and 4 workers, and across reruns with
//!   the same seeds (the injector lives entirely on the submit side);
//! * **self-healing** — a failed DPR swap rolls back and the squeeze
//!   still lands later; an SEU is scrub-repaired with a finite MTTR;
//! * **no observer effect** — replaying with an *empty* fault plan is
//!   bit-identical to replaying with no plan at all.

use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{trace, Coordinator, ServeConfig, TraceConfig, TraceOutcome};
use forgemorph::design::DesignConfig;
use forgemorph::fault::FaultPlan;
use forgemorph::graph::zoo;
use forgemorph::morph;
use forgemorph::pe::{FpRep, ZYNQ_7100};

const FRAMES: usize = 240;
const RATE_HZ: f64 = 4000.0;
const SEED: u64 = 7;

fn start(workers: usize) -> Coordinator {
    let net = zoo::mnist();
    // same Table III-class mapping as the power-loop tests
    let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
    let paths = morph::depth_ladder(&net);
    let spec = BackendSpec::sim(net, design, ZYNQ_7100, paths);
    let cfg = ServeConfig { workers, external_pacing: true, ..ServeConfig::default() };
    Coordinator::start(cfg, spec).expect("start")
}

/// Step-trace replay under a fault spec (`None` = no injection at all).
fn replay(workers: usize, spec: Option<&str>) -> TraceOutcome {
    let mut coord = start(workers);
    let cap = trace::default_squeeze_cap(&coord.path_energy_rows());
    let events = trace::step(FRAMES as f64 / RATE_HZ, cap);
    let plan = spec.map(|s| {
        FaultPlan::parse_spec(s, FRAMES, RATE_HZ, SEED).expect("fault spec")
    });
    coord
        .replay_trace(
            &events,
            &TraceConfig { frames: FRAMES, rate_hz: RATE_HZ, seed: SEED },
            plan.as_ref(),
        )
        .expect("replay")
}

fn assert_zero_loss(out: &TraceOutcome, what: &str) {
    assert_eq!(out.answered, out.submitted, "{what}: lost responses");
    assert_eq!(out.submitted, FRAMES, "{what}: wrong submission count");
    assert_eq!(
        out.ok + out.degraded + out.failed,
        out.answered,
        "{what}: terminal accounting does not cover every answer"
    );
}

#[test]
fn every_builtin_fault_kind_loses_nothing() {
    for spec in ["transient", "stall", "swapfail", "seu", FaultPlan::storm_spec()] {
        let out = replay(4, Some(spec));
        assert_zero_loss(&out, spec);
        assert!(
            out.metrics.faults_injected > 0,
            "{spec}: plan armed but nothing injected"
        );
    }
}

#[test]
fn fault_and_decision_logs_identical_across_workers_and_reruns() {
    let reference = replay(1, Some(FaultPlan::storm_spec()));
    assert!(!reference.fault_log().is_empty(), "storm produced no fault log");
    assert!(!reference.decision_log().is_empty(), "storm produced no decisions");
    for (workers, what) in [(4usize, "workers=4"), (1, "rerun workers=1")] {
        let got = replay(workers, Some(FaultPlan::storm_spec()));
        assert_eq!(reference.fault_log(), got.fault_log(), "fault log diverged: {what}");
        assert_eq!(
            reference.decision_log(),
            got.decision_log(),
            "decision log diverged: {what}"
        );
        assert_eq!(
            reference.frames_by_path, got.frames_by_path,
            "frame accounting diverged: {what}"
        );
        let (a, b) = (&reference.metrics, &got.metrics);
        assert_eq!(a.faults_injected, b.faults_injected, "{what}");
        assert_eq!(a.swaps_rolled_back, b.swaps_rolled_back, "{what}");
        assert_eq!(a.scrub_repairs, b.scrub_repairs, "{what}");
        assert_eq!(a.recoveries, b.recoveries, "{what}");
        assert_eq!(
            (reference.ok, reference.degraded, reference.failed),
            (got.ok, got.degraded, got.failed),
            "terminal dispositions diverged: {what}"
        );
    }
}

#[test]
fn failed_swap_rolls_back_then_the_squeeze_still_lands() {
    let out = replay(1, Some("swapfail"));
    assert_zero_loss(&out, "swapfail");
    assert!(out.metrics.swaps_rolled_back >= 1, "armed swap failure never struck");
    // the rollback is in the fault log...
    assert!(
        out.fault_log().contains("fault swapfail:") && out.fault_log().contains("rolled back"),
        "no rollback record:\n{}",
        out.fault_log()
    );
    // ...and after the cooldown the governor still commits the down-shift
    assert!(
        out.switches.iter().any(|s| s.from == "d3_w100" && s.to != "d3_w100"),
        "squeeze never committed after rollback: {:?}",
        out.switches
    );
    // the retried commit fires strictly after the rolled-back attempt
    let rollback_frame = out
        .fault_log()
        .lines()
        .find(|l| l.contains("fault swapfail:"))
        .and_then(|l| l[7..12].parse::<usize>().ok())
        .expect("rollback frame");
    assert!(
        out.switches.iter().any(|s| s.frame > rollback_frame),
        "no committed switch after the frame-{rollback_frame} rollback"
    );
}

#[test]
fn seu_is_scrubbed_with_finite_mttr() {
    let out = replay(1, Some("seu"));
    assert_zero_loss(&out, "seu");
    assert!(out.metrics.scrub_repairs >= 1, "scrubber never repaired the flip");
    assert!(
        out.metrics.mean_time_to_recovery_ms() > 0.0,
        "repair recorded but MTTR is zero"
    );
    // the misrouting window marks its frames Degraded, never lost
    assert!(out.degraded > 0, "SEU window produced no degraded responses");
    assert!(out.fault_log().contains("seu: bit"), "{}", out.fault_log());
    assert!(out.fault_log().contains("scrub: crc mismatch repaired"), "{}", out.fault_log());
}

#[test]
fn transient_faults_retry_to_success() {
    let out = replay(4, Some("transient"));
    assert_zero_loss(&out, "transient");
    // default transient clauses fail one attempt -> every strike retries
    // through to a successful (non-failed) terminal response
    assert!(out.metrics.retries >= 1, "no retries recorded");
    assert_eq!(out.failed, 0, "single-attempt transients must heal via retry");
    assert!(out.fault_log().contains("fault transient:"), "{}", out.fault_log());
}

#[test]
fn empty_fault_plan_is_bit_identical_to_no_plan() {
    let mut with_empty = {
        let mut coord = start(1);
        let cap = trace::default_squeeze_cap(&coord.path_energy_rows());
        let events = trace::step(FRAMES as f64 / RATE_HZ, cap);
        let plan = FaultPlan::empty(SEED);
        coord
            .replay_trace(
                &events,
                &TraceConfig { frames: FRAMES, rate_hz: RATE_HZ, seed: SEED },
                Some(&plan),
            )
            .expect("replay")
    };
    let without = replay(1, None);
    assert_eq!(with_empty.decision_log(), without.decision_log());
    assert_eq!(with_empty.frames_by_path, without.frames_by_path);
    assert_eq!(with_empty.energy_mj, without.energy_mj, "energy integral diverged");
    assert!(with_empty.fault_log().is_empty());
    assert_eq!(with_empty.metrics.faults_injected, 0);
    // an armed-but-empty plan still renders the fault summary lines; the
    // no-plan outcome must not (bit-identical legacy output) — flattening
    // the flag makes the remaining summaries comparable
    assert!(with_empty.injection);
    assert!(!without.injection);
    with_empty.injection = false;
    assert_eq!(with_empty.render_summary(), without.render_summary());
}
