//! ONNX front-end integration tests (ISSUE 10 acceptance).
//!
//! Three layers of guarantees:
//!
//! 1. **Golden round-trip** — every zoo model exported to ONNX wire
//!    bytes and re-imported produces a `Network` *and* a scheduled
//!    `StagePlan` bit-identical to its hand-built twin (assert_eq on
//!    the serialized plan JSON). This is the contract that lets
//!    imported models flow through design/sim/rtl/dse/morph unchanged.
//! 2. **Malformed-protobuf corpus** — truncated varints, wrong wire
//!    types, deprecated groups, recursive depth bombs, zero-dim
//!    tensors: every one yields an offset-carrying error, never a
//!    panic.
//! 3. **Totality properties** — decode survives arbitrary random bytes
//!    and random single-byte corruptions of a valid export.

use forgemorph::graph::{passes, zoo};
use forgemorph::onnx::{self, ImportError};
use forgemorph::util::prop;
use forgemorph::util::rng::Rng;

// ---------------------------------------------------------------------------
// wire-building helpers (hand-rolled, mirroring the decoder's test kit)
// ---------------------------------------------------------------------------

fn v(mut x: u64) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            return out;
        }
        out.push(b | 0x80);
    }
}

fn key(field: u32, wire: u32) -> Vec<u8> {
    v(u64::from((field << 3) | wire))
}

fn ld(field: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = key(field, 2);
    out.extend(v(payload.len() as u64));
    out.extend_from_slice(payload);
    out
}

fn vint(field: u32, x: u64) -> Vec<u8> {
    let mut out = key(field, 0);
    out.extend(v(x));
    out
}

/// ValueInfoProto: name + NCHW float tensor type.
fn value_info(name: &str, dims: &[u64]) -> Vec<u8> {
    let mut shape = Vec::new();
    for &d in dims {
        shape.extend(ld(1, &vint(1, d)));
    }
    let mut tensor_type = vint(1, 1); // elem_type FLOAT
    tensor_type.extend(ld(2, &shape));
    let ty = ld(1, &tensor_type);
    let mut vi = ld(1, name.as_bytes());
    vi.extend(ld(2, &ty));
    vi
}

/// Shape-only TensorProto initializer.
fn tensor(name: &str, dims: &[u64]) -> Vec<u8> {
    let mut t = Vec::new();
    for &d in dims {
        t.extend(vint(1, d));
    }
    t.extend(vint(2, 1)); // data_type FLOAT
    t.extend(ld(8, name.as_bytes()));
    t
}

/// NodeProto with no attributes.
fn node(op: &str, name: &str, inputs: &[&str], outputs: &[&str]) -> Vec<u8> {
    let mut n = Vec::new();
    for i in inputs {
        n.extend(ld(1, i.as_bytes()));
    }
    for o in outputs {
        n.extend(ld(2, o.as_bytes()));
    }
    n.extend(ld(3, name.as_bytes()));
    n.extend(ld(4, op.as_bytes()));
    n
}

/// ModelProto wrapping a GraphProto payload.
fn model(graph: &[u8]) -> Vec<u8> {
    let mut m = vint(1, 8); // ir_version
    m.extend(ld(7, graph));
    m
}

fn decode_err(bytes: &[u8]) -> onnx::DecodeError {
    match onnx::import_bytes(bytes).unwrap_err() {
        ImportError::Decode(e) => e,
        ImportError::Lower(m) => panic!("expected decode error, got lowering error: {m}"),
    }
}

fn lower_err(bytes: &[u8]) -> String {
    match onnx::import_bytes(bytes).unwrap_err() {
        ImportError::Lower(m) => m,
        ImportError::Decode(e) => panic!("expected lowering error, got decode error: {e}"),
    }
}

// ---------------------------------------------------------------------------
// 1. golden round-trip: exported zoo model == hand-built twin
// ---------------------------------------------------------------------------

/// Export -> import -> assert the Network AND the scheduled StagePlan
/// are bit-identical to the hand-built twin.
fn assert_round_trip(name: &str) {
    let twin = zoo::by_name(name).expect("zoo model");
    let bytes = onnx::encode(&twin).expect("zoo model encodes");
    let imported = onnx::import_bytes(&bytes)
        .unwrap_or_else(|e| panic!("importing exported '{name}': {e}"));

    assert_eq!(imported.name, twin.name, "{name}: model name");
    assert_eq!(imported.layers, twin.layers, "{name}: layer list");
    assert_eq!(imported.connections, twin.connections, "{name}: connection table");

    let plan_twin = passes::schedule(&twin).expect("twin schedules");
    let plan_imported = passes::schedule(&imported).expect("imported model schedules");
    assert_eq!(
        plan_imported.to_json().to_string(),
        plan_twin.to_json().to_string(),
        "{name}: StagePlan JSON must be bit-identical"
    );
}

#[test]
fn resnet50_round_trips_bit_identical() {
    assert_round_trip("resnet50");
}

#[test]
fn unet_tiny_round_trips_bit_identical() {
    assert_round_trip("unet_tiny");
}

#[test]
fn yolov5l_round_trips_bit_identical() {
    assert_round_trip("yolov5l");
}

#[test]
fn every_zoo_model_round_trips_bit_identical() {
    for name in zoo::NAMES {
        assert_round_trip(name);
    }
}

// ---------------------------------------------------------------------------
// 2. malformed-protobuf corpus: offset-carrying errors, never panics
// ---------------------------------------------------------------------------

#[test]
fn truncated_varint_reports_offset() {
    // field 1 (ir_version) tag, then a lone continuation byte
    let e = decode_err(&[0x08, 0xFF]);
    assert_eq!(e.at, 1, "{e}");
    assert!(e.msg.contains("truncated varint"), "{e}");
    assert!(e.to_string().contains("at byte 1"), "{e}");
}

#[test]
fn length_past_end_reports_offset() {
    // graph field claims 100 payload bytes, buffer has 0
    let mut bytes = key(7, 2);
    bytes.extend(v(100));
    let e = decode_err(&bytes);
    assert_eq!(e.at, 1, "{e}");
    assert!(e.msg.contains("runs past end"), "{e}");
}

#[test]
fn wrong_wire_type_reports_field() {
    // graph (field 7) must be length-delimited, sent as varint
    let e = decode_err(&vint(7, 5));
    assert!(e.msg.contains("wire type"), "{e}");
    assert!(e.msg.contains("field 7"), "{e}");
}

#[test]
fn deprecated_group_wire_type_rejected() {
    // unknown field 9 with start-group wire type 3
    let e = decode_err(&key(9, 3));
    assert!(e.msg.contains("group"), "{e}");
}

#[test]
fn recursive_depth_bomb_errors_instead_of_overflowing() {
    // If-style nodes whose attribute `g` re-enters GraphProto, nested
    // far past MAX_GRAPH_DEPTH
    let mut g = Vec::new();
    for _ in 0..(onnx::proto::MAX_GRAPH_DEPTH + 8) {
        let mut attr = ld(1, b"body");
        attr.extend(ld(6, &g)); // AttributeProto.g
        let mut n = ld(4, b"If");
        n.extend(ld(5, &attr));
        g = ld(1, &n);
    }
    let e = decode_err(&model(&g));
    assert!(e.msg.contains("nesting exceeds depth"), "{e}");
}

#[test]
fn zero_dim_input_rejected() {
    let mut g = ld(11, &value_info("t0", &[1, 0, 8, 8]));
    g.extend(ld(1, &node("Relu", "act", &["t0"], &["t1"])));
    g.extend(ld(12, &value_info("t1", &[1, 0, 8, 8])));
    let m = lower_err(&model(&g));
    assert!(m.contains("zero-sized dimension"), "{m}");
}

#[test]
fn zero_dim_weight_tensor_rejected() {
    let mut g = ld(11, &value_info("t0", &[1, 3, 8, 8]));
    g.extend(ld(5, &tensor("w0", &[8, 3, 0, 0])));
    g.extend(ld(1, &node("Conv", "stem", &["t0", "w0"], &["t1"])));
    g.extend(ld(12, &value_info("t1", &[1, 8, 8, 8])));
    let m = lower_err(&model(&g));
    assert!(m.contains("positive"), "{m}");
    assert!(m.contains("w0"), "{m}");
}

#[test]
fn unsupported_op_gets_did_you_mean_with_node_and_inputs() {
    let mut g = ld(11, &value_info("t0", &[1, 3, 8, 8]));
    g.extend(ld(5, &tensor("w0", &[8, 3, 3, 3])));
    g.extend(ld(1, &node("Convv", "stem", &["t0", "w0"], &["t1"])));
    g.extend(ld(12, &value_info("t1", &[1, 8, 8, 8])));
    let m = lower_err(&model(&g));
    assert!(m.contains("unsupported op 'Convv'"), "{m}");
    assert!(m.contains("(did you mean 'Conv'?)"), "{m}");
    // the error names the node and its inputs
    assert!(m.contains("'stem'"), "{m}");
    assert!(m.contains("t0, w0"), "{m}");
}

#[test]
fn empty_file_is_a_lowering_error_not_a_panic() {
    // zero bytes decode to an empty ModelProto (all fields default);
    // lowering then reports the missing graph
    let m = lower_err(&[]);
    assert!(m.contains("no graph"), "{m}");
}

// ---------------------------------------------------------------------------
// 3. totality properties
// ---------------------------------------------------------------------------

#[test]
fn prop_decode_is_total_on_random_bytes() {
    prop::check(
        "onnx-decode-total",
        400,
        0xC0FFEE,
        |rng: &mut Rng| {
            let len = rng.below(256);
            (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect::<Vec<u8>>()
        },
        |bytes| {
            // must return Ok or Err — any panic fails the harness
            let _ = onnx::import_bytes(bytes);
            Ok(())
        },
    );
}

#[test]
fn prop_import_survives_single_byte_corruption() {
    let clean = onnx::encode(&zoo::mnist()).expect("encodes");
    prop::check(
        "onnx-corrupt-byte",
        300,
        7,
        |rng: &mut Rng| (rng.below(clean.len()), (rng.next_u64() & 0xff) as u8),
        |&(pos, val)| {
            let mut bytes = clean.clone();
            bytes[pos] = val;
            // decoding/lowering may fail (that's the point) but must
            // never panic; a surviving import must still validate
            if let Ok(net) = onnx::import_bytes(&bytes) {
                net.validate().map_err(|e| format!("corrupt import passed but invalid: {e}"))?;
            }
            Ok(())
        },
    );
}
