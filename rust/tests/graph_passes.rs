//! Pass-pipeline integration tests: the StagePlan refactor contract.
//!
//! * **Equivalence** — the pre-StagePlan analytical model and cycle
//!   simulator (verbatim reference copies of the old layer-list walks)
//!   produce bit-identical numbers to the new plan-driven paths on every
//!   legacy chain/residual zoo model, so `BENCH_dse.json` stays
//!   comparable across the refactor.
//! * **Scheduling properties** — every StagePlan respects every dataflow
//!   edge (producer before consumer), gene order matches the legacy
//!   chromosome layout, shape inference agrees pre/post relu-fusion.
//! * **Branchy end-to-end** — the faithful yolov5l (real Concat /
//!   Upsample / SPPF nodes) runs through evaluate, simulate, RTL
//!   emission, DSE and depth/width morphs.

use forgemorph::design::{self, DesignConfig, DesignEval, LayerMapping};
use forgemorph::graph::passes::{self, EdgeKind};
use forgemorph::graph::{shapes, zoo, LayerKind, Network, NetworkBuilder, Padding};
use forgemorph::morph;
use forgemorph::pe::conv::ConvPe;
use forgemorph::pe::fc::FcPe;
use forgemorph::pe::pool::{PoolKind, PoolPe};
use forgemorph::pe::{Blanking, Device, FpRep, Resources, ZYNQ_7100};
use forgemorph::power::{Activity, PowerModel};
use forgemorph::sim::{self, GateMask};
use forgemorph::util::rng::Rng;

/// Legacy chain/residual models whose numbers must survive the refactor.
fn legacy_models() -> Vec<Network> {
    vec![
        zoo::mnist(),
        zoo::svhn(),
        zoo::cifar10(),
        zoo::resnet50(),
        zoo::mobilenet_v2(),
        zoo::squeezenet(),
    ]
}

// ---------------------------------------------------------------------------
// Reference implementations: the pre-StagePlan layer-list walks, verbatim
// ---------------------------------------------------------------------------

/// The old `design::evaluate`: walks `net.layers` carrying `prev_p` in
/// list order. Legacy layer kinds only.
fn reference_evaluate(net: &Network, cfg: &DesignConfig, device: &Device) -> DesignEval {
    let shp = shapes::infer(net).unwrap();
    let blank = Blanking::default();
    let mut mappings = Vec::with_capacity(net.layers.len());
    let mut total = Resources::default();
    let mut conv_idx = 0usize;
    let mut prev_p = 1usize;
    let mut first_conv_seen = false;

    for layer in &net.layers {
        let inp = shp.input(layer.id);
        let mapping = match &layer.kind {
            LayerKind::Conv { filters, k, relu, .. } => {
                let p = cfg.parallelism[conv_idx];
                conv_idx += 1;
                let lanes_in = prev_p.min(inp.c).max(1);
                let pe_count = p * lanes_in;
                let pe = ConvPe {
                    k: *k,
                    fm_w: inp.w,
                    fm_h: inp.h,
                    rep: cfg.rep,
                    relu: *relu,
                    first_layer: !first_conv_seen,
                };
                first_conv_seen = true;
                let simd = if cfg.rep == FpRep::Int8 { 2 } else { 1 };
                let serial = filters.div_ceil(p * simd) * inp.c.div_ceil(lanes_in);
                let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
                let m = LayerMapping {
                    layer_id: layer.id,
                    name: layer.name.clone(),
                    pe_count,
                    serial_factor: serial,
                    occupancy_cycles: pass * serial,
                    fill_cycles: (k - 1) * (inp.w + blank.back_porch + blank.front_porch)
                        + pe.overhead_cycles(),
                    resources: pe.resources().scale(pe_count),
                };
                prev_p = p;
                m
            }
            LayerKind::DwConv { k, relu, .. } => {
                let p = cfg.parallelism[conv_idx];
                conv_idx += 1;
                let pe = ConvPe {
                    k: *k,
                    fm_w: inp.w,
                    fm_h: inp.h,
                    rep: cfg.rep,
                    relu: *relu,
                    first_layer: !first_conv_seen,
                };
                first_conv_seen = true;
                let lanes = p.min(inp.c).max(1);
                let simd = if cfg.rep == FpRep::Int8 { 2 } else { 1 };
                let serial = inp.c.div_ceil(lanes * simd);
                let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
                let m = LayerMapping {
                    layer_id: layer.id,
                    name: layer.name.clone(),
                    pe_count: lanes,
                    serial_factor: serial,
                    occupancy_cycles: pass * serial,
                    fill_cycles: (k - 1) * (inp.w + blank.back_porch + blank.front_porch)
                        + pe.overhead_cycles(),
                    resources: pe.resources().scale(lanes),
                };
                prev_p = lanes;
                m
            }
            LayerKind::MaxPool { k, stride } | LayerKind::AvgPool { k, stride } => {
                let kind = if matches!(layer.kind, LayerKind::MaxPool { .. }) {
                    PoolKind::Max
                } else {
                    PoolKind::Avg
                };
                let pe = PoolPe { k: *k, stride: *stride, fm_w: inp.w, fm_h: inp.h, kind };
                let lanes = prev_p.min(inp.c).max(1);
                let serial = inp.c.div_ceil(lanes);
                let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
                LayerMapping {
                    layer_id: layer.id,
                    name: layer.name.clone(),
                    pe_count: lanes,
                    serial_factor: serial,
                    occupancy_cycles: pass * serial,
                    fill_cycles: (k - 1) * (inp.w + blank.back_porch + blank.front_porch) + 6,
                    resources: pe.resources().scale(lanes),
                }
            }
            LayerKind::Fc { out, .. } => {
                let n_pe = prev_p.min(inp.c).max(1);
                let pe = FcPe {
                    fc_out: *out,
                    n_pe,
                    channels: inp.c,
                    fm_w: inp.w,
                    fm_h: inp.h.max(1),
                };
                LayerMapping {
                    layer_id: layer.id,
                    name: layer.name.clone(),
                    pe_count: *out * n_pe,
                    serial_factor: pe.parallelism(),
                    occupancy_cycles: pe.latency_cycles(blank),
                    fill_cycles: 4,
                    resources: pe.resources(),
                }
            }
            LayerKind::ResidualAdd { .. } => LayerMapping {
                layer_id: layer.id,
                name: layer.name.clone(),
                pe_count: prev_p,
                serial_factor: 1,
                occupancy_cycles: 0,
                fill_cycles: 1,
                resources: Resources { dsp: 0, lut: 24 * prev_p, ff: 16 * prev_p, bram: 0 },
            },
            LayerKind::GlobalAvgPool => LayerMapping {
                layer_id: layer.id,
                name: layer.name.clone(),
                pe_count: prev_p,
                serial_factor: 1,
                occupancy_cycles: (inp.w + 4) * inp.h,
                fill_cycles: 4,
                resources: Resources { dsp: 0, lut: 60 * prev_p, ff: 32 * prev_p, bram: 0 },
            },
            LayerKind::Softmax => LayerMapping {
                layer_id: layer.id,
                name: layer.name.clone(),
                pe_count: 1,
                serial_factor: 1,
                occupancy_cycles: inp.c * 4,
                fill_cycles: 8,
                resources: Resources { dsp: 2, lut: 900, ff: 600, bram: 1 },
            },
            LayerKind::Input { .. } => LayerMapping {
                layer_id: layer.id,
                name: layer.name.clone(),
                pe_count: 0,
                serial_factor: 1,
                occupancy_cycles: 0,
                fill_cycles: 0,
                resources: Resources::default(),
            },
            other => panic!("reference model does not cover {other:?}"),
        };
        total = total.add(&mapping.resources);
        mappings.push(mapping);
    }

    let (in_h, in_w, _) = net.input_dims();
    let source = (in_w + blank.back_porch + blank.front_porch) * in_h;
    let fill: usize = mappings.iter().map(|m| m.fill_cycles).sum();
    let serialized: usize = mappings
        .iter()
        .filter(|m| m.serial_factor > 1)
        .map(|m| m.occupancy_cycles)
        .sum();
    let period = mappings
        .iter()
        .map(|m| m.occupancy_cycles)
        .max()
        .unwrap_or(1)
        .max(source);
    let latency = source + fill + serialized;
    let total_pes = mappings
        .iter()
        .filter(|m| {
            matches!(
                net.layers[m.layer_id].kind,
                LayerKind::Conv { .. } | LayerKind::DwConv { .. }
            )
        })
        .map(|m| m.pe_count)
        .sum();

    DesignEval {
        mappings,
        resources: total,
        total_pes,
        latency_cycles: latency,
        period_cycles: period,
        clock_mhz: device.clock_mhz,
    }
}

const ROW_BUBBLE: u64 = 2;
const PASS_DRAIN: u64 = 6;

fn mask_active(gate: &GateMask, block: usize) -> bool {
    gate.block_active.get(block).copied().unwrap_or(true)
}

/// The old `sim::simulate_with`: walks `net.layers` in list order with a
/// conv-ordinal gate counter. Legacy layer kinds only.
fn reference_simulate(
    net: &Network,
    device: &Device,
    gate: &GateMask,
    eval: &DesignEval,
) -> (u64, u64, f64, Resources, Vec<(u64, u64, bool)>) {
    let blank = Blanking::default();
    let shapes = shapes::infer(net).unwrap();
    let mut per_stage = Vec::new();
    let mut conv_block = 0usize;
    let mut gated_from_here = false;
    let (in_h, in_w, _) = net.input_dims();
    let mut bottleneck: u64 = in_h as u64
        * ((in_w + blank.back_porch + blank.front_porch) as u64 + ROW_BUBBLE);
    let mut fill_total: u64 = 0;
    let mut serialized_total: u64 = 0;
    let pm = PowerModel::default();
    let mut active_dsp = 0usize;
    let mut active_lut = 0usize;
    let mut active_bram = 0usize;

    for layer in &net.layers {
        let m = &eval.mappings[layer.id];
        let is_conv = matches!(
            layer.kind,
            LayerKind::Conv { .. } | LayerKind::DwConv { .. }
        );
        if is_conv {
            let b = conv_block;
            conv_block += 1;
            if !mask_active(gate, b) {
                gated_from_here = true;
            }
        }
        if gated_from_here {
            per_stage.push((0, 0, true));
            continue;
        }
        let serial = if is_conv && gate.width_fraction < 1.0 {
            ((m.serial_factor as f64) * gate.width_fraction).ceil().max(1.0) as u64
        } else {
            m.serial_factor as u64
        };
        let weight_reload = match layer.kind {
            LayerKind::Conv { k, .. } | LayerKind::DwConv { k, .. } => (k * k) as u64,
            _ => 0,
        };
        let inp = shapes.input(layer.id);
        let replay_cycles = inp.h as u64
            * ((inp.w + blank.back_porch + blank.front_porch) as u64 + ROW_BUBBLE);
        let busy = serial * replay_cycles.max(1)
            + serial.saturating_sub(1) * (PASS_DRAIN + weight_reload);
        bottleneck = bottleneck.max(busy);
        fill_total += m.fill_cycles as u64;
        if serial > 1 {
            serialized_total += busy;
        }
        let lane_scale = if is_conv { gate.width_fraction } else { 1.0 };
        active_dsp += (m.resources.dsp as f64 * lane_scale) as usize;
        active_lut += (m.resources.lut as f64 * lane_scale) as usize;
        active_bram += m.resources.bram;
        per_stage.push((busy, serial, false));
    }

    let source = in_h as u64
        * ((in_w + blank.back_porch + blank.front_porch) as u64 + ROW_BUBBLE);
    let latency = source + fill_total + serialized_total;
    let active_res =
        Resources { dsp: active_dsp, lut: active_lut, ff: 0, bram: active_bram };
    let power = pm.total_mw(&active_res, device.clock_mhz, Activity::default());
    let stages = per_stage.len();
    let elaborated = Resources {
        dsp: eval.resources.dsp,
        lut: eval.resources.lut + 140 * stages + eval.resources.lut / 25,
        ff: eval.resources.ff + 90 * stages,
        bram: eval.resources.bram,
    };
    (latency, bottleneck, power, elaborated, per_stage)
}

fn configs_for(net: &Network, rng: &mut Rng) -> Vec<DesignConfig> {
    let bounds = net.conv_filter_bounds();
    let mut cfgs = vec![
        DesignConfig::uniform(net, 1, FpRep::Int16),
        DesignConfig::uniform(net, 2, FpRep::Int8),
        DesignConfig::uniform(net, 4, FpRep::Int16),
        DesignConfig::full(net, FpRep::Int8),
    ];
    for _ in 0..3 {
        let parallelism: Vec<usize> =
            bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect();
        let rep = if rng.chance(0.5) { FpRep::Int8 } else { FpRep::Int16 };
        cfgs.push(DesignConfig { parallelism, rep });
    }
    cfgs
}

fn mapping_tuples(e: &DesignEval) -> Vec<(usize, String, usize, usize, usize, usize, Resources)> {
    e.mappings
        .iter()
        .map(|m| {
            (
                m.layer_id,
                m.name.clone(),
                m.pe_count,
                m.serial_factor,
                m.occupancy_cycles,
                m.fill_cycles,
                m.resources,
            )
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Equivalence: chain/residual models, old walk == new StagePlan path
// ---------------------------------------------------------------------------

#[test]
fn evaluate_identical_through_stageplan_path() {
    let mut rng = Rng::new(71);
    for net in legacy_models() {
        for cfg in configs_for(&net, &mut rng) {
            let old = reference_evaluate(&net, &cfg, &ZYNQ_7100);
            let new = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
            assert_eq!(old.resources, new.resources, "{} resources", net.name);
            assert_eq!(old.total_pes, new.total_pes, "{} PEs", net.name);
            assert_eq!(old.latency_cycles, new.latency_cycles, "{} latency", net.name);
            assert_eq!(old.period_cycles, new.period_cycles, "{} period", net.name);
            assert_eq!(
                mapping_tuples(&old),
                mapping_tuples(&new),
                "{} per-stage mappings",
                net.name
            );
        }
    }
}

#[test]
fn simulate_identical_through_stageplan_path() {
    let mut rng = Rng::new(72);
    for net in legacy_models() {
        let n_blocks = net.conv_layer_ids().len();
        let masks = [
            GateMask::all_active(),
            GateMask::depth_prefix(&net, n_blocks.div_ceil(2)),
            GateMask::width(0.5),
        ];
        for cfg in configs_for(&net, &mut rng).into_iter().take(3) {
            let eval = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
            let plan = passes::schedule(&net).unwrap();
            for mask in &masks {
                let (lat, per, pw, res, stages) =
                    reference_simulate(&net, &ZYNQ_7100, mask, &eval);
                let new = sim::simulate_with(&plan, &ZYNQ_7100, mask, &eval);
                assert_eq!(lat, new.latency_cycles, "{} latency", net.name);
                assert_eq!(per, new.period_cycles, "{} period", net.name);
                assert!((pw - new.power_mw).abs() < 1e-9, "{} power", net.name);
                assert_eq!(res, new.resources, "{} resources", net.name);
                let new_stages: Vec<(u64, u64, bool)> = new
                    .per_stage
                    .iter()
                    .map(|s| (s.busy_cycles, s.passes, s.gated))
                    .collect();
                assert_eq!(stages, new_stages, "{} per-stage", net.name);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scheduling properties
// ---------------------------------------------------------------------------

#[test]
fn every_plan_respects_every_edge() {
    for name in zoo::NAMES {
        let net = zoo::by_name(name).unwrap();
        let plan = passes::schedule(&net).unwrap();
        // stage ids are a permutation-free topological order: every edge
        // goes forward and matches a pred slot of its consumer
        for e in &plan.edges {
            assert!(e.src < e.dst, "{name}: edge ({}, {}) not forward", e.src, e.dst);
            assert!(
                plan.stages[e.dst].preds.contains(&e.src),
                "{name}: edge ({}, {}) missing from preds",
                e.src,
                e.dst
            );
        }
        for s in &plan.stages {
            for &p in &s.preds {
                assert!(p < s.id, "{name}: stage {} consumes later stage {p}", s.id);
            }
        }
        // gene order == legacy chromosome layout
        assert_eq!(plan.conv_bounds(), net.conv_filter_bounds(), "{name}");
        assert_eq!(plan.gate_blocks, net.conv_layer_ids().len(), "{name}");
        // conv slots are dense and in stage order
        let slots: Vec<usize> = plan
            .stages
            .iter()
            .filter_map(|s| s.conv_slot)
            .collect();
        assert_eq!(slots, (0..slots.len()).collect::<Vec<_>>(), "{name}");
    }
}

#[test]
fn shape_inference_agrees_pre_and_post_fusion() {
    // sprinkle standalone relu nodes into random chains; canonicalize
    // must fold them without changing any surviving layer's output shape
    let mut rng = Rng::new(9);
    for _ in 0..20 {
        let mut b = NetworkBuilder::new("fuzz", 32, 32, 3);
        let mut convs = 0;
        let mut pools = 0;
        for _ in 0..rng.below(6) + 1 {
            b = b.conv(rng.below(8) + 1, 3, 1, Padding::Same, false);
            convs += 1;
            if rng.chance(0.6) {
                b = b.relu();
            }
            // cap pooling so the 32x32 frame never shrinks below 4x4
            if pools < 3 && rng.chance(0.3) {
                b = b.maxpool(2, 2);
                pools += 1;
                if rng.chance(0.3) {
                    b = b.relu(); // unfusable: stays a stage
                }
            }
        }
        let net = b.build();
        assert!(convs > 0);
        let pre = shapes::infer(&net).unwrap();
        let canon = passes::canonicalize(&net).unwrap();
        let post = shapes::infer(&canon).unwrap();
        assert_eq!(pre.final_output(), post.final_output());
        // every canonical layer keeps the shape of its source layer: walk
        // both nets front-to-back skipping folded relus in the original
        let mut ci = 0usize;
        for l in &net.layers {
            if ci < canon.layers.len() && canon.layers[ci].name == l.name {
                assert_eq!(
                    pre.output(l.id),
                    post.output(ci),
                    "shape drift at {}",
                    l.name
                );
                ci += 1;
            }
        }
        assert_eq!(ci, canon.layers.len(), "canonical layers unmatched");
        // and the canonical net evaluates
        let cfg = DesignConfig::uniform(&net, 2, FpRep::Int16);
        assert!(design::evaluate(&net, &cfg, &ZYNQ_7100).is_ok());
    }
}

// ---------------------------------------------------------------------------
// Branchy end-to-end (acceptance: yolov5l through the whole compiler)
// ---------------------------------------------------------------------------

#[test]
fn yolov5l_full_compiler_pipeline() {
    let net = zoo::yolov5l();
    assert!(net.has_branches(), "faithful yolo must carry real concats");

    // evaluate: branch buffering lands in the resource model
    let plan = passes::schedule(&net).unwrap();
    let cfg = DesignConfig::uniform(&net, 2, FpRep::Int8);
    let eval = design::evaluate_plan(&plan, &cfg, &ZYNQ_7100).unwrap();
    let branch_words: usize = plan
        .edges
        .iter()
        .filter(|e| e.kind == EdgeKind::Branch)
        .map(|e| e.fifo_words)
        .sum();
    assert!(branch_words > 0, "yolo must buffer branch edges");
    let concat_bram: usize = plan
        .stages
        .iter()
        .filter(|s| matches!(s.kind, LayerKind::Concat { .. }))
        .map(|s| eval.mappings[s.id].resources.bram)
        .sum();
    assert!(concat_bram > 0, "branch buffers must cost BRAM");

    // simulate under full, depth-morphed and width-morphed masks
    let full = sim::simulate_with(&plan, &ZYNQ_7100, &GateMask::all_active(), &eval);
    let depth_path = morph::MorphPath {
        name: "d8_w100".into(),
        depth: 8,
        width_pct: 100,
        accuracy: 0.5,
        params: 1,
        macs: 1,
    };
    let width_path = morph::MorphPath {
        name: "d104_w50".into(),
        depth: plan.gate_blocks,
        width_pct: 50,
        accuracy: 0.5,
        params: 1,
        macs: 2,
    };
    let d_mask = morph::gate_mask_for(&net, &depth_path).unwrap();
    let w_mask = morph::gate_mask_for(&net, &width_path).unwrap();
    let deep = sim::simulate_with(&plan, &ZYNQ_7100, &d_mask, &eval);
    let wide = sim::simulate_with(&plan, &ZYNQ_7100, &w_mask, &eval);
    assert!(deep.latency_cycles < full.latency_cycles, "depth morph must cut latency");
    assert!(deep.power_mw < full.power_mw);
    assert!(wide.period_cycles <= full.period_cycles);

    // RTL emission
    let bundle = forgemorph::rtl::emit_plan(&plan, &cfg, &eval);
    let top = bundle.file(&format!("{}.v", bundle.top_name)).unwrap();
    assert!(top.contains("concat_mux #(") && top.contains("spp_pe #("));

    // DSE end-to-end on the 104-gene chromosome (quick profile)
    let dse_cfg = forgemorph::dse::DseConfig {
        population: 12,
        generations: 2,
        seed: 3,
        rep: FpRep::Int8,
        ..forgemorph::dse::DseConfig::default()
    };
    let res = forgemorph::dse::run(&net, &ZYNQ_7100, &dse_cfg);
    assert!(!res.pareto.is_empty(), "unconstrained search must yield a front");
    for c in &res.pareto {
        assert_eq!(c.config.parallelism.len(), plan.conv_stage_ids.len());
    }
}

#[test]
fn unet_tiny_serves_as_second_branchy_workload() {
    let net = zoo::unet_tiny();
    let plan = passes::schedule(&net).unwrap();
    assert!(!plan.is_chain());
    let cfg = DesignConfig::uniform(&net, 4, FpRep::Int16);
    let eval = design::evaluate_plan(&plan, &cfg, &ZYNQ_7100).unwrap();
    let r = sim::simulate_with(&plan, &ZYNQ_7100, &GateMask::all_active(), &eval);
    assert!(r.latency_cycles >= eval.latency_cycles as u64);
    // skip-concat branches buffer the encoder fmaps
    let words: usize = plan.edges.iter().map(|e| e.fifo_words).sum();
    // e1 (96*96*16) + e2 (48*48*32) encoder taps
    assert_eq!(words, 96 * 96 * 16 + 48 * 48 * 32);
}
