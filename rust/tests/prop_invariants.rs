//! Property-based invariants over the coordinator-side algorithms
//! (in-tree harness — no proptest in the offline vendor set).

use forgemorph::coordinator::BatchPolicy;
use forgemorph::design::{self, DesignConfig};
use forgemorph::dse;
use forgemorph::graph::zoo;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::quant::QParams;
use forgemorph::sim::{self, GateMask};
use forgemorph::util::json::Json;
use forgemorph::util::prop::{check, ensure};
use forgemorph::util::rng::Rng;

/// Random valid design point for a random small model.
fn random_design(rng: &mut Rng) -> (forgemorph::graph::Network, DesignConfig) {
    let net = match rng.below(3) {
        0 => zoo::mnist(),
        1 => zoo::svhn(),
        _ => zoo::cifar10(),
    };
    let bounds = net.conv_filter_bounds();
    let parallelism = bounds
        .iter()
        .map(|&ub| rng.range(1, ub as i64) as usize)
        .collect();
    let rep = if rng.chance(0.5) { FpRep::Int8 } else { FpRep::Int16 };
    (net, DesignConfig { parallelism, rep })
}

#[test]
fn prop_estimate_never_exceeds_simulation() {
    // The analytical model is optimistic by construction: the simulator
    // adds handshake/drain/reload overheads — Fig. 10's error direction.
    check("est<=sim", 60, 11, random_design, |(net, cfg)| {
        let est = design::evaluate(net, cfg, &ZYNQ_7100).map_err(|e| e.to_string())?;
        let sim = sim::simulate(net, cfg, &ZYNQ_7100, &GateMask::all_active());
        ensure(
            sim.latency_cycles >= est.latency_cycles as u64,
            format!("sim {} < est {}", sim.latency_cycles, est.latency_cycles),
        )?;
        ensure(
            (sim.latency_cycles as f64) < est.latency_cycles as f64 * 1.6,
            format!("sim {} too far above est {}", sim.latency_cycles, est.latency_cycles),
        )
    });
}

#[test]
fn prop_dsp_bram_estimates_exact() {
    // DSP and BRAM are explicitly instantiated: estimator == elaboration
    // (the paper's 0%-error columns in Table III).
    check("dsp-bram-exact", 40, 12, random_design, |(net, cfg)| {
        let est = design::evaluate(net, cfg, &ZYNQ_7100).map_err(|e| e.to_string())?;
        let sim = sim::simulate(net, cfg, &ZYNQ_7100, &GateMask::all_active());
        ensure(est.resources.dsp == sim.resources.dsp, "DSP mismatch")?;
        ensure(est.resources.bram == sim.resources.bram, "BRAM mismatch")
    });
}

#[test]
fn prop_segment_composition_and_roofline_sound() {
    // The DSE's segment kernel must reproduce the monolithic evaluator
    // bitwise (the stage cache's correctness argument), and the roofline
    // pre-filter's lower bounds must never exceed the truth.
    check("segment-compose", 60, 15, random_design, |(net, cfg)| {
        let ev = design::Evaluator::new(net, &ZYNQ_7100).map_err(|e| e.to_string())?;
        let mono = ev.objectives(&cfg.parallelism, cfg.rep).map_err(|e| e.to_string())?;
        let composed = ev.compose((0..ev.n_stages()).map(|s| {
            ev.stage_fit_packed(ev.stage_key(s, &cfg.parallelism), cfg.rep)
        }));
        ensure(composed == mono, "segment composition diverged from monolithic evaluator")?;
        let gb = dse::roofline::GeneBounds::new(&ev, cfg.rep);
        ensure(
            gb.latency_cycles_lb(&cfg.parallelism) <= mono.latency_cycles,
            "roofline latency bound above truth",
        )?;
        ensure(
            gb.dsp_lb(&cfg.parallelism) <= mono.resources.dsp,
            "roofline dsp bound above truth",
        )
    });
}

#[test]
fn prop_gating_never_increases_cost() {
    check("gating-monotone", 40, 13, random_design, |(net, cfg)| {
        let full = sim::simulate(net, cfg, &ZYNQ_7100, &GateMask::all_active());
        for depth in 1..net.conv_layer_ids().len() {
            let g = sim::simulate(net, cfg, &ZYNQ_7100, &GateMask::depth_prefix(net, depth));
            ensure(
                g.latency_cycles <= full.latency_cycles,
                format!("depth {depth} latency grew"),
            )?;
            ensure(g.power_mw <= full.power_mw + 1e-9, format!("depth {depth} power grew"))?;
        }
        let w = sim::simulate(net, cfg, &ZYNQ_7100, &GateMask::width(0.5));
        ensure(w.power_mw <= full.power_mw + 1e-9, "width gating power grew")
    });
}

#[test]
fn prop_pareto_front_sound() {
    // Every front is mutually non-dominated, within chromosome bounds,
    // and constraint-satisfying — for random constraint draws.
    check(
        "pareto-sound",
        8,
        14,
        |rng: &mut Rng| {
            let dsp_cap = 200 + rng.below(3000);
            let seed = rng.next_u64();
            (dsp_cap, seed)
        },
        |&(dsp_cap, seed)| {
            let net = zoo::mnist();
            let cfg = dse::DseConfig {
                population: 24,
                generations: 6,
                seed,
                constraints: dse::Constraints {
                    latency_ms: None,
                    dsp: Some(dsp_cap),
                    lut: None,
                    bram: None,
                    power_mw: None,
                },
                ..dse::DseConfig::default()
            };
            let res = dse::run(&net, &ZYNQ_7100, &cfg);
            let bounds = net.conv_filter_bounds();
            for c in &res.pareto {
                ensure(c.objectives.dsp <= dsp_cap, "constraint violated")?;
                for (p, ub) in c.config.parallelism.iter().zip(&bounds) {
                    ensure(*p >= 1 && p <= ub, "gene out of bounds")?;
                }
            }
            for a in &res.pareto {
                for b in &res.pareto {
                    if a.config.parallelism != b.config.parallelism {
                        ensure(
                            !a.objectives.dominates(&b.objectives)
                                || !b.objectives.dominates(&a.objectives),
                            "mutual domination",
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dse_invariant_under_threads_and_memo() {
    // the engine contract: thread count and memo cache are observationally
    // invisible — for random seeds/configurations the Pareto front is
    // bit-identical across (threads=1, memo) / (threads=3, memo) /
    // (threads=3, no-memo)
    check(
        "dse-threads-memo-invariant",
        6,
        27,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let net = zoo::svhn();
            let base = dse::DseConfig {
                population: 20,
                generations: 5,
                seed,
                constraints: dse::Constraints::device(&ZYNQ_7100),
                ..dse::DseConfig::default()
            };
            let runs = [
                dse::run(&net, &ZYNQ_7100, &base),
                dse::run(&net, &ZYNQ_7100, &dse::DseConfig { threads: 3, ..base.clone() }),
                dse::run(
                    &net,
                    &ZYNQ_7100,
                    &dse::DseConfig { threads: 3, memo: false, ..base.clone() },
                ),
            ];
            let fp = |r: &dse::DseResult| -> Vec<(Vec<usize>, u64)> {
                r.pareto
                    .iter()
                    .map(|c| (c.config.parallelism.clone(), c.objectives.latency_ms.to_bits()))
                    .collect()
            };
            ensure(fp(&runs[0]) == fp(&runs[1]), "threads changed the front")?;
            ensure(fp(&runs[0]) == fp(&runs[2]), "memo cache changed the front")?;
            ensure(
                runs.iter().all(|r| r.evaluations == runs[0].evaluations),
                "evaluation count drifted",
            )?;
            Ok(())
        },
    );
}

#[test]
fn prop_power_model_monotone_in_every_axis() {
    // total_mw must be monotone (non-decreasing) in each resource count,
    // the clock, and both activity axes — the property the governor's
    // ordering arguments and the DSE power constraint rely on.
    use forgemorph::pe::Resources;
    use forgemorph::power::{Activity, PowerModel};
    check(
        "power-monotone",
        400,
        21,
        |rng: &mut Rng| {
            let res = Resources {
                dsp: rng.below(2000),
                lut: rng.below(400_000),
                ff: rng.below(500_000),
                bram: rng.below(1500),
            };
            let clock = 50.0 + rng.f64() * 400.0;
            let act = Activity {
                active_fraction: rng.f64(),
                toggle_rate: rng.f64(),
            };
            // which axis to bump, and by how much
            let axis = rng.below(6);
            let bump = 1.0 + rng.f64() * 4.0;
            (res, clock, act, axis, bump)
        },
        |&(res, clock, act, axis, bump)| {
            let m = PowerModel::default();
            let base = m.total_mw(&res, clock, act);
            let mut res2 = res;
            let mut clock2 = clock;
            let mut act2 = act;
            match axis {
                0 => res2.dsp += bump as usize + 1,
                1 => res2.lut += (bump * 1000.0) as usize + 1,
                2 => res2.bram += bump as usize + 1,
                3 => clock2 += bump * 10.0,
                4 => act2.active_fraction = (act.active_fraction + bump / 10.0).min(1.0),
                _ => act2.toggle_rate = (act.toggle_rate + bump / 10.0).min(1.0),
            }
            let bumped = m.total_mw(&res2, clock2, act2);
            ensure(
                bumped >= base - 1e-9,
                format!("axis {axis}: {base} -> {bumped} decreased"),
            )
        },
    );
}

#[test]
fn prop_energy_telemetry_merge_associative() {
    // shard metrics merge like a monoid: (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) on
    // every energy/power field (up to f64 rounding), so cross-shard
    // aggregation order can never change a run report.
    use forgemorph::coordinator::ServingMetrics;
    use forgemorph::power::{Activity, PathEnergy};
    check(
        "energy-merge-assoc",
        200,
        22,
        |rng: &mut Rng| {
            let mk = |rng: &mut Rng| {
                let mut m = ServingMetrics::default();
                for path in ["d1_w100", "d2_w100", "d3_w100"] {
                    if rng.chance(0.7) {
                        let row = PathEnergy {
                            name: path.into(),
                            activity: Activity::default(),
                            power_mw: 400.0 + rng.f64() * 600.0,
                            frame_ms: 0.05 + rng.f64() * 2.0,
                        };
                        m.record_energy(&row, rng.below(50) + 1);
                    }
                }
                m
            };
            (mk(rng), mk(rng), mk(rng))
        },
        |(a, b, c)| {
            let left = {
                let mut x = a.clone();
                x.merge(b);
                x.merge(c);
                x
            };
            let right = {
                let mut bc = b.clone();
                bc.merge(c);
                let mut x = a.clone();
                x.merge(&bc);
                x
            };
            let close = |x: f64, y: f64| (x - y).abs() <= 1e-9 * (1.0 + x.abs().max(y.abs()));
            ensure(close(left.energy_j, right.energy_j), "energy_j not associative")?;
            ensure(
                close(left.power_mw_ms, right.power_mw_ms),
                "power integral not associative",
            )?;
            ensure(close(left.modeled_ms, right.modeled_ms), "modeled_ms not associative")?;
            ensure(
                close(left.mean_power_mw(), right.mean_power_mw()),
                "mean power not associative",
            )?;
            ensure(
                left.energy_mj_by_path.keys().eq(right.energy_mj_by_path.keys()),
                "per-path keys diverge",
            )?;
            for (k, v) in &left.energy_mj_by_path {
                ensure(
                    close(*v, right.energy_mj_by_path[k]),
                    format!("per-path energy diverges on {k}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_backoff_schedule_deterministic() {
    // retry instants must be a pure function of (policy, request id,
    // attempt): evaluation order, chunking and repetition are all
    // unobservable — the invariant the canonical fault log's
    // worker-count byte-identity rests on. Bounded and (for jitter < 1)
    // strictly increasing, so a retry never lands before its
    // predecessor.
    use forgemorph::fault::RetryPolicy;
    check(
        "backoff-deterministic",
        200,
        33,
        |rng: &mut Rng| {
            let policy = RetryPolicy {
                max_retries: (rng.below(4) + 1) as u32,
                base_ms: 0.1 + rng.f64() * 2.0,
                factor: 1.2 + rng.f64() * 2.0,
                jitter_pct: rng.f64() * 0.9,
                seed: rng.next_u64(),
            };
            let id = rng.next_u64();
            (policy, id)
        },
        |&(policy, id)| {
            let retries = policy.max_retries;
            let forward = policy.instants_ms(id, retries);
            // re-derive each instant out of order and standalone: both
            // must reproduce the forward schedule exactly
            for a in (0..retries).rev() {
                let again = policy.instants_ms(id, retries);
                ensure(
                    again[a as usize].to_bits() == forward[a as usize].to_bits(),
                    format!("instant {a} not reproducible"),
                )?;
                let single = policy.backoff_ms(id, a);
                ensure(
                    single.to_bits() == policy.backoff_ms(id, a).to_bits(),
                    format!("backoff_ms({id}, {a}) impure"),
                )?;
            }
            let mut prev = 0.0;
            for (a, &t) in forward.iter().enumerate() {
                ensure(t > prev, format!("instant {a} not increasing: {forward:?}"))?;
                let nominal = policy.base_ms * policy.factor.powi(a as i32);
                let lo = nominal * (1.0 - policy.jitter_pct) - 1e-12;
                let hi = nominal * (1.0 + policy.jitter_pct) + 1e-12;
                ensure(
                    t - prev >= lo && t - prev <= hi,
                    format!("delay {a} outside jitter band: {}", t - prev),
                )?;
                prev = t;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fault_counter_merge_associative() {
    // the fault-telemetry counters merge like a monoid, exactly like the
    // energy fields: aggregation order across shards can never change
    // the `report faults` numbers (integer counters are exact; MTTR
    // numerator/denominator merge independently).
    use forgemorph::coordinator::ServingMetrics;
    check(
        "fault-merge-assoc",
        200,
        34,
        |rng: &mut Rng| {
            let mk = |rng: &mut Rng| {
                let mut m = ServingMetrics::default();
                m.faults_injected = rng.below(20) as u64;
                m.retries = rng.below(20) as u64;
                m.timeouts = rng.below(10) as u64;
                m.failed_requests = rng.below(10) as u64;
                m.degraded_requests = rng.below(30) as u64;
                m.swaps_rolled_back = rng.below(4) as u64;
                m.scrub_repairs = rng.below(4) as u64;
                m.recoveries = rng.below(6) as u64;
                m.recovery_ms_sum = rng.f64() * 40.0;
                m
            };
            (mk(rng), mk(rng), mk(rng))
        },
        |(a, b, c)| {
            let left = {
                let mut x = a.clone();
                x.merge(b);
                x.merge(c);
                x
            };
            let right = {
                let mut bc = b.clone();
                bc.merge(c);
                let mut x = a.clone();
                x.merge(&bc);
                x
            };
            let ints = |m: &ServingMetrics| {
                [
                    m.faults_injected,
                    m.retries,
                    m.timeouts,
                    m.failed_requests,
                    m.degraded_requests,
                    m.swaps_rolled_back,
                    m.scrub_repairs,
                    m.recoveries,
                ]
            };
            ensure(ints(&left) == ints(&right), "integer fault counters not associative")?;
            let close = |x: f64, y: f64| (x - y).abs() <= 1e-9 * (1.0 + x.abs().max(y.abs()));
            ensure(
                close(left.recovery_ms_sum, right.recovery_ms_sum),
                "recovery sum not associative",
            )?;
            ensure(
                close(left.mean_time_to_recovery_ms(), right.mean_time_to_recovery_ms()),
                "MTTR not associative",
            )
        },
    );
}

#[test]
fn prop_quant_roundtrip_bounded() {
    check(
        "quant-bound",
        300,
        15,
        |rng: &mut Rng| {
            let n = rng.below(100) + 1;
            let bits = if rng.chance(0.5) { 8 } else { 16 };
            let scale = 10f64.powf(rng.f64() * 6.0 - 3.0);
            let data: Vec<f64> = (0..n).map(|_| rng.gauss() * scale).collect();
            (data, bits)
        },
        |(data, bits)| {
            let p = QParams::fit(data, *bits);
            for &x in data {
                ensure(
                    (x - p.fake_quant(x)).abs() <= p.scale / 2.0 + 1e-9,
                    format!("roundtrip error at {x}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_never_exceeds_supported_sizes() {
    use std::time::{Duration, Instant};
    check(
        "batcher-sizes",
        200,
        16,
        |rng: &mut Rng| {
            let mut sizes = vec![1usize];
            if rng.chance(0.8) {
                sizes.push(1 << (1 + rng.below(4)));
            }
            let pending = rng.below(40);
            let waited_us = rng.below(5000) as u64;
            (sizes, pending, waited_us)
        },
        |(sizes, pending, waited_us)| {
            let policy = BatchPolicy::new(sizes.clone(), Duration::from_micros(1000));
            let now = Instant::now();
            let oldest = if *pending > 0 {
                Some(now - Duration::from_micros(*waited_us))
            } else {
                None
            };
            match policy.decide(*pending, oldest, now) {
                None => {
                    // must only wait if under max batch and under deadline
                    ensure(
                        *pending < policy.max_size() && (*pending == 0 || *waited_us < 1000),
                        "policy waited when it should have fired",
                    )
                }
                Some(size) => {
                    ensure(sizes.contains(&size), format!("unsupported size {size}"))?;
                    ensure(
                        size <= (*pending).max(1),
                        format!("batch {size} exceeds pending {pending}"),
                    )
                }
            }
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.gauss() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| char::from(32 + rng.below(94) as u8))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(
        "json-roundtrip",
        300,
        17,
        |rng: &mut Rng| random_json(rng, 3),
        |v| {
            let text = v.to_string();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            ensure(&back == v, format!("roundtrip mismatch: {text}"))
        },
    );
}

#[test]
fn prop_blocked_kernels_bitwise_match_reference() {
    // the PR 6 tentpole invariant: the blocked im2col microkernels and
    // the retained scalar reference kernels agree bit-for-bit on every
    // output — random shapes, morphed widths and batch sizes, inputs
    // with post-ReLU sparsity, gradients with relu_bwd zeros. The
    // blocked core keeps the reference reduction order per accumulator,
    // so this holds exactly, not approximately (DESIGN.md §11).
    use forgemorph::distill::{tensor, tensor_ref};
    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
    check(
        "blocked-kernels-bitwise",
        40,
        29,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let n = rng.below(3) + 1;
            let h = rng.below(6) + 1;
            let w = rng.below(6) + 1;
            let k = if rng.chance(0.25) { 1 } else { 3 };
            let cin = rng.below(4) + 1;
            let cout = rng.below(5) + 1;
            let cin_a = rng.below(cin) + 1;
            let cout_a = rng.below(cout) + 1;
            let conv = tensor::Conv {
                w: (0..k * k * cin * cout).map(|_| (rng.gauss() * 0.3) as f32).collect(),
                b: (0..cout).map(|_| (rng.gauss() * 0.1) as f32).collect(),
                k,
                cin,
                cout,
            };
            let sparse = |rng: &mut Rng, len: usize, p_zero: f64| -> Vec<f32> {
                (0..len)
                    .map(|_| {
                        let v = (rng.gauss() * 0.6) as f32;
                        if rng.chance(p_zero) { 0.0 } else { v }
                    })
                    .collect()
            };
            let x = sparse(&mut rng, n * h * w * cin_a, 0.4);

            let fwd_ref = tensor_ref::conv_fwd(&x, n, h, w, &conv, cin_a, cout_a);
            let fwd_blk = tensor::conv_fwd(&x, n, h, w, &conv, cin_a, cout_a);
            ensure(bits(&fwd_ref) == bits(&fwd_blk), "conv_fwd diverged")?;

            let dpre = sparse(&mut rng, n * h * w * cout_a, 0.5);
            let compute_dx = rng.chance(0.8);
            let mut gw_r = vec![0.0f32; conv.w.len()];
            let mut gb_r = vec![0.0f32; conv.b.len()];
            let dx_r = tensor_ref::conv_bwd(
                &x,
                n,
                h,
                w,
                &conv,
                cin_a,
                cout_a,
                &dpre,
                &mut gw_r,
                &mut gb_r,
                compute_dx,
            );
            let mut gw_b = vec![0.0f32; conv.w.len()];
            let mut gb_b = vec![0.0f32; conv.b.len()];
            let dx_b = tensor::conv_bwd(
                &x,
                n,
                h,
                w,
                &conv,
                cin_a,
                cout_a,
                &dpre,
                &mut gw_b,
                &mut gb_b,
                compute_dx,
            );
            ensure(bits(&gw_r) == bits(&gw_b), "conv_bwd gw diverged")?;
            ensure(bits(&gb_r) == bits(&gb_b), "conv_bwd gb diverged")?;
            ensure(bits(&dx_r) == bits(&dx_b), "conv_bwd dx diverged")?;

            let classes = rng.below(5) + 2;
            let dim = h * w * cin_a;
            let head = tensor::Dense {
                w: (0..dim * classes).map(|_| (rng.gauss() * 0.3) as f32).collect(),
                b: (0..classes).map(|_| (rng.gauss() * 0.1) as f32).collect(),
                dim,
                classes,
            };
            let xf = sparse(&mut rng, n * dim, 0.4);
            let logits_ref = tensor_ref::fc_fwd(&xf, n, &head);
            let logits_blk = tensor::fc_fwd(&xf, n, &head);
            ensure(bits(&logits_ref) == bits(&logits_blk), "fc_fwd diverged")?;

            let dlogits = sparse(&mut rng, n * classes, 0.3);
            let mut hw_r = vec![0.0f32; head.w.len()];
            let mut hb_r = vec![0.0f32; head.b.len()];
            let fdx_r = tensor_ref::fc_bwd(&xf, n, &head, &dlogits, &mut hw_r, &mut hb_r);
            let mut hw_b = vec![0.0f32; head.w.len()];
            let mut hb_b = vec![0.0f32; head.b.len()];
            let fdx_b = tensor::fc_bwd(&xf, n, &head, &dlogits, &mut hw_b, &mut hb_b);
            ensure(bits(&hw_r) == bits(&hw_b), "fc_bwd gw diverged")?;
            ensure(bits(&hb_r) == bits(&hb_b), "fc_bwd gb diverged")?;
            ensure(bits(&fdx_r) == bits(&fdx_b), "fc_bwd dx diverged")
        },
    );
}

#[test]
fn prop_distill_profile_invariant_under_threads() {
    // ISSUE 6 acceptance: the AccuracyProfile JSON is byte-identical
    // between --threads 1 and --threads 4, across random training and
    // dataset seeds (the in-module distill test covers threads 0/1/3 on
    // one seed). Calibration schedules are pre-drawn serially and heads
    // merge in ladder order, so worker count is unobservable.
    use forgemorph::distill::{self, DistillConfig, DistillSpec};
    check(
        "distill-threads-invariant",
        2,
        31,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let spec = DistillSpec::tiny();
            let ds = spec.dataset(96, 32, seed);
            let emit = |threads: usize| {
                let cfg = DistillConfig {
                    epochs_per_stage: 1,
                    batch: 32,
                    seed,
                    threads,
                    ..DistillConfig::default()
                };
                distill::train_profile(&spec, &ds, &cfg).to_json()
            };
            ensure(emit(1) == emit(4), "profile JSON diverged between 1 and 4 threads")
        },
    );
}

#[test]
fn prop_balanced_designs_fit_device() {
    check(
        "balanced-fits",
        12,
        18,
        |rng: &mut Rng| match rng.below(4) {
            0 => zoo::mnist(),
            1 => zoo::svhn(),
            2 => zoo::cifar10(),
            _ => zoo::squeezenet(),
        },
        |net| {
            let cfg = DesignConfig::balanced(net, FpRep::Int8, &ZYNQ_7100);
            let eval = design::evaluate(net, &cfg, &ZYNQ_7100).map_err(|e| e.to_string())?;
            ensure(eval.fits(&ZYNQ_7100), "balanced design exceeds device budget")
        },
    );
}
