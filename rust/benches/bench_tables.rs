//! `cargo bench` harness #1: regenerate EVERY paper table and figure.
//!
//! No criterion in the offline vendor set — this is a plain
//! `harness = false` binary that times each experiment, prints the full
//! regenerated block, and finishes with a timing summary. The printed
//! blocks are the reproduction deliverable (EXPERIMENTS.md quotes them).

use std::time::Instant;

fn main() {
    let experiments: &[&str] = &[
        "table1", "table2", "fig2", "fig8", "fig10", "table3", "table4",
        "table5", "table6", "fig11", "fig12", "backends", "graphs", "distill",
        "power",
    ];
    let mut timings: Vec<(&str, f64)> = Vec::new();
    for id in experiments {
        let t0 = Instant::now();
        let block = forgemorph::report::by_name(id).expect("known experiment id");
        let dt = t0.elapsed().as_secs_f64();
        println!("{block}");
        timings.push((id, dt));
    }

    println!("\n=== bench_tables timing summary ===");
    println!("{:<10} {:>10}", "experiment", "seconds");
    for (id, dt) in &timings {
        println!("{id:<10} {dt:>10.3}");
    }
    let total: f64 = timings.iter().map(|(_, t)| t).sum();
    println!("{:<10} {total:>10.3}", "TOTAL");
}
