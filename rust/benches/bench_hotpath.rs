//! `cargo bench` harness #2: hot-path microbenchmarks (the §Perf data).
//!
//! Times the inner loops that dominate each L3 pipeline stage:
//! * `design::evaluate`        — the DSE fitness function (called 10^4-10^5x per search)
//! * `dse generation step`     — full MOGA generation incl. NSGA-II sort
//! * `nsga2::sort_fronts`      — dominance sorting alone
//! * `dse engine scaling`      — resnet50 search at 1/2/4/8 threads plus
//!                               memo-cache effectiveness, vs the pre-PR
//!                               serial no-cache shape; writes
//!                               `BENCH_dse.json` at the repo root
//! * `distill kernels + ladder`— blocked im2col microkernels vs the scalar
//!                               reference (conv fwd/bwd GFLOP/s, im2col
//!                               pack ms), then end-to-end DistillCycle
//!                               ladder training: threads=0 scalar baseline
//!                               vs the blocked core at 1/2/4 threads;
//!                               writes `BENCH_distill.json` at the repo root
//! * `surrogate logits`        — packed batch pass vs scalar per-frame dots
//! * `sim::simulate`           — cycle simulation of small & big models
//! * `rtl::emit`               — Verilog generation
//! * `json parse`              — manifest parsing
//! * `engine.execute`          — PJRT inference per path/batch (needs artifacts)
//! * `serving throughput`      — sharded coordinator on the sim backend at
//!                               1/2/4 worker shards (the scaling curve)
//!
//! Plain timing harness (no criterion offline): warmup + fixed-duration
//! sampling, reports mean / p50 / min per iteration.

use std::time::{Duration, Instant};

use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{trace, Coordinator, ServeConfig, TraceConfig};
use forgemorph::design::{self, DesignConfig};
use forgemorph::fault::FaultPlan;
use forgemorph::dse;
use forgemorph::graph::zoo;
use forgemorph::morph;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::rtl;
use forgemorph::sim::{self, GateMask};
use forgemorph::util::rng::Rng;

/// Run `f` repeatedly for ~`budget` (after warmup), report stats.
fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) {
    // warmup
    let warm_until = Instant::now() + budget / 5;
    while Instant::now() < warm_until {
        f();
    }
    let mut samples = Vec::new();
    let until = Instant::now() + budget;
    while Instant::now() < until && samples.len() < 100_000 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let min = samples[0];
    println!(
        "{name:<44} {:>8} iters  mean {:>12}  p50 {:>12}  min {:>12}",
        samples.len(),
        fmt_t(mean),
        fmt_t(p50),
        fmt_t(min)
    );
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

fn main() {
    let budget = Duration::from_millis(
        std::env::var("BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(800),
    );
    println!("=== bench_hotpath (budget {budget:?} per case) ===");

    // --- DSE fitness function --------------------------------------------
    let mnist = zoo::mnist();
    let cifar = zoo::cifar10();
    let mobilenet = zoo::mobilenet_v2();
    let cfg_m = DesignConfig::uniform(&mnist, 4, FpRep::Int16);
    let cfg_c = DesignConfig::uniform(&cifar, 4, FpRep::Int16);
    let cfg_mb = DesignConfig::uniform(&mobilenet, 4, FpRep::Int8);
    bench("design::evaluate mnist", budget, || {
        std::hint::black_box(design::evaluate(&mnist, &cfg_m, &ZYNQ_7100).unwrap());
    });
    bench("design::evaluate cifar10", budget, || {
        std::hint::black_box(design::evaluate(&cifar, &cfg_c, &ZYNQ_7100).unwrap());
    });
    bench("design::evaluate mobilenetv2 (52 conv)", budget, || {
        std::hint::black_box(design::evaluate(&mobilenet, &cfg_mb, &ZYNQ_7100).unwrap());
    });

    // --- pass pipeline + branchy dataflow graphs --------------------------
    {
        let yolo = zoo::yolov5l();
        let unet = zoo::unet_tiny();
        let plan_y = forgemorph::graph::passes::schedule(&yolo).unwrap();
        let cfg_y = DesignConfig::uniform(&yolo, 2, FpRep::Int8);
        let cfg_u = DesignConfig::uniform(&unet, 4, FpRep::Int16);
        bench("passes::schedule yolov5l (141 stages)", budget, || {
            std::hint::black_box(forgemorph::graph::passes::schedule(&yolo).unwrap());
        });
        bench("design::evaluate_plan yolov5l (104 conv)", budget, || {
            std::hint::black_box(
                design::evaluate_plan(&plan_y, &cfg_y, &ZYNQ_7100).unwrap(),
            );
        });
        bench("sim::simulate unet_tiny (branchy)", budget, || {
            std::hint::black_box(sim::simulate(
                &unet,
                &cfg_u,
                &ZYNQ_7100,
                &GateMask::all_active(),
            ));
        });
    }

    // --- MOGA generation --------------------------------------------------
    bench("dse::run cifar10 pop=32 gens=1", budget, || {
        let cfg = dse::DseConfig {
            population: 32,
            generations: 1,
            seed: 1,
            ..dse::DseConfig::default()
        };
        std::hint::black_box(dse::run(&cifar, &ZYNQ_7100, &cfg));
    });
    bench("dse::run cifar10 pop=96 gens=10", Duration::from_secs(4), || {
        let cfg = dse::DseConfig {
            population: 96,
            generations: 10,
            seed: 1,
            ..dse::DseConfig::default()
        };
        std::hint::black_box(dse::run(&cifar, &ZYNQ_7100, &cfg));
    });

    // --- NSGA-II sorting ---------------------------------------------------
    {
        let mut rng = Rng::new(9);
        let pop: Vec<dse::Candidate> = (0..256)
            .map(|_| {
                dse::evaluate_candidate(
                    &mnist,
                    mnist
                        .conv_filter_bounds()
                        .iter()
                        .map(|&ub| rng.range(1, ub as i64) as usize)
                        .collect(),
                    FpRep::Int16,
                    &ZYNQ_7100,
                    &dse::Constraints::none(),
                )
            })
            .collect();
        bench("nsga2::sort_fronts n=256", budget, || {
            std::hint::black_box(dse::nsga2::sort_fronts(&pop));
        });
    }

    // --- DSE engine: thread scaling + memo-cache effectiveness --------------
    // The §Perf acceptance numbers: the parallel, memoized engine on the
    // resnet50 search vs the pre-PR shape (serial, no chromosome cache).
    // Machine-readable results go to BENCH_dse.json at the repo root so
    // the perf trajectory is tracked across PRs.
    {
        let resnet = zoo::resnet50();
        let evaluator = design::Evaluator::new(&resnet, &ZYNQ_7100).unwrap();
        let bounds = resnet.conv_filter_bounds();

        // per-candidate analytical-eval cost on random chromosomes
        let mut rng = Rng::new(17);
        let genes: Vec<Vec<usize>> = (0..512)
            .map(|_| bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect())
            .collect();
        for g in &genes {
            std::hint::black_box(evaluator.objectives(g, FpRep::Int16).unwrap()); // warmup
        }
        let mut samples: Vec<f64> = Vec::with_capacity(genes.len());
        for g in &genes {
            let t0 = Instant::now();
            std::hint::black_box(evaluator.objectives(g, FpRep::Int16).unwrap());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let eval_mean_us = samples.iter().sum::<f64>() / samples.len() as f64 * 1e6;
        let eval_p50_us = samples[samples.len() / 2] * 1e6;
        println!(
            "evaluator.objectives resnet50 per-candidate: mean {eval_mean_us:.3} us, p50 {eval_p50_us:.3} us"
        );

        let pop = 128usize;
        let gens = 16usize;
        let mk = |threads: usize, memo: bool| dse::DseConfig {
            population: pop,
            generations: gens,
            seed: 5,
            threads,
            memo,
            constraints: dse::Constraints::device(&ZYNQ_7100),
            ..dse::DseConfig::default()
        };
        // best-of-3 wall time; any run's result serves for telemetry
        // (the engine is deterministic, so all repeats are identical)
        let time_cfg = |cfg: &dse::DseConfig| -> (f64, dse::DseResult) {
            let mut best = f64::INFINITY;
            let mut res = None;
            for _ in 0..3 {
                let r = dse::run(&resnet, &ZYNQ_7100, cfg);
                best = best.min(r.wall_ms);
                res = Some(r);
            }
            (best, res.unwrap())
        };

        let (serial_ms, serial_res) = time_cfg(&mk(1, false));
        let front_of = |res: &dse::DseResult| -> Vec<Vec<usize>> {
            res.pareto.iter().map(|c| c.config.parallelism.clone()).collect()
        };
        let reference_front = front_of(&serial_res);
        println!(
            "dse::run resnet50 pop={pop} gens={gens} serial no-memo (pre-PR shape): {serial_ms:>9.2} ms"
        );

        let mut rows = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let (ms, res) = time_cfg(&mk(threads, true));
            let identical = front_of(&res) == reference_front;
            let speedup = serial_ms / ms;
            println!(
                "dse::run resnet50 threads={threads} memo:           {ms:>9.2} ms  \
                 ({speedup:.2}x vs pre-PR, cache hit {:.1}%, stage hit {:.1}%, \
                 front identical: {identical})",
                res.cache_hit_rate() * 100.0,
                res.stage_hit_rate() * 100.0
            );
            // gens + 1 evaluation batches per run: init population + one
            // per generation (matches evaluations = pop * (gens + 1))
            rows.push(format!(
                "    {{\"threads\": {threads}, \"wall_ms\": {ms:.3}, \"gen_step_ms\": {:.4}, \
                 \"speedup_vs_serial_nomemo\": {speedup:.3}, \"cache_hit_rate\": {:.4}, \
                 \"stage_hit_rate\": {:.4}, \"front_identical\": {identical}}}",
                ms / (gens + 1) as f64,
                res.cache_hit_rate(),
                res.stage_hit_rate()
            ));
        }

        // second big model from the acceptance list: yolov5l, serial
        // no-memo vs 8 threads + memo
        let yolo = zoo::yolov5l();
        let time_yolo = |cfg: &dse::DseConfig| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                best = best.min(dse::run(&yolo, &ZYNQ_7100, cfg).wall_ms);
            }
            best
        };
        let yolo_serial_ms = time_yolo(&mk(1, false));
        let yolo_8t_ms = time_yolo(&mk(8, true));
        let yolo_speedup = yolo_serial_ms / yolo_8t_ms;
        println!(
            "dse::run yolov5l serial no-memo {yolo_serial_ms:>9.2} ms | \
             8 threads memo {yolo_8t_ms:>9.2} ms ({yolo_speedup:.2}x)"
        );

        // population scaling: the stage cache's headline. Bigger
        // populations revisit the same (stage, gene-window) points far
        // more often, so the segment-cached engine's wall-clock grows
        // sublinearly in population while the chromosome-memo-only
        // engine scales ~linearly with unique chromosomes. Per-pop
        // speedup_stage_vs_chromo and the pop-512-at-pop-128-budget
        // ratio carry "speedup" in the key so bench-check gates them.
        let scale_gens = 12usize;
        let yolo_cfg = |population: usize, stage_memo: bool| dse::DseConfig {
            population,
            generations: scale_gens,
            seed: 5,
            threads: 8,
            stage_memo,
            constraints: dse::Constraints::device(&ZYNQ_7100),
            ..dse::DseConfig::default()
        };
        let mut scale_rows = Vec::new();
        let mut pop128_chromo_ms = f64::INFINITY;
        let mut pop512_stage_ms = f64::INFINITY;
        for population in [128usize, 512, 2048] {
            // pop 2048 is ~16x the pop-128 work even cached; single shot
            let reps = if population >= 2048 { 1 } else { 3 };
            let time_pop = |stage_memo: bool| -> (f64, dse::DseResult) {
                let mut best = f64::INFINITY;
                let mut res = None;
                for _ in 0..reps {
                    let r = dse::run(&yolo, &ZYNQ_7100, &yolo_cfg(population, stage_memo));
                    best = best.min(r.wall_ms);
                    res = Some(r);
                }
                (best, res.unwrap())
            };
            let (stage_ms, stage_res) = time_pop(true);
            let (chromo_ms, chromo_res) = time_pop(false);
            let identical = front_of(&stage_res) == front_of(&chromo_res);
            let speedup = chromo_ms / stage_ms;
            if population == 128 {
                pop128_chromo_ms = chromo_ms;
            }
            if population == 512 {
                pop512_stage_ms = stage_ms;
            }
            println!(
                "dse::run yolov5l pop={population} 8t: stage cache {stage_ms:>9.2} ms \
                 (stage hit {:.1}%) vs chromosome memo only {chromo_ms:>9.2} ms \
                 ({speedup:.2}x, front identical: {identical})",
                stage_res.stage_hit_rate() * 100.0
            );
            scale_rows.push(format!(
                "    {{\"population\": {population}, \"wall_ms\": {stage_ms:.3}, \
                 \"stage_hit_rate\": {:.4}, \"chromo_memo_wall_ms\": {chromo_ms:.3}, \
                 \"speedup_stage_vs_chromo\": {speedup:.3}, \"front_identical\": {identical}}}",
                stage_res.stage_hit_rate()
            ));
        }
        // >= 1.0 means population 512 with the stage cache fits in the
        // old population-128 wall-clock budget: 4x effective throughput
        let pop512_vs_128 = pop128_chromo_ms / pop512_stage_ms;
        println!(
            "dse::run yolov5l pop=512 stage-cached vs pop=128 chromosome-memo: \
             {pop512_vs_128:.2}x budget ratio"
        );

        let json = format!(
            "{{\n  \"bench\": \"dse_engine\",\n  \"model\": \"resnet50\",\n  \
             \"population\": {pop},\n  \"generations\": {gens},\n  \
             \"eval_us\": {{\"mean\": {eval_mean_us:.4}, \"p50\": {eval_p50_us:.4}}},\n  \
             \"serial_nomemo_wall_ms\": {serial_ms:.3},\n  \
             \"serial_nomemo_gen_step_ms\": {:.4},\n  \"threads\": [\n{}\n  ],\n  \
             \"yolov5l\": {{\"serial_nomemo_wall_ms\": {yolo_serial_ms:.3}, \
             \"threads8_memo_wall_ms\": {yolo_8t_ms:.3}, \
             \"speedup\": {yolo_speedup:.3}}},\n  \
             \"population_scaling\": [\n{}\n  ],\n  \
             \"yolov5l_pop512_stage_vs_pop128_chromo_speedup\": {pop512_vs_128:.3}\n}}\n",
            serial_ms / (gens + 1) as f64,
            rows.join(",\n"),
            scale_rows.join(",\n")
        );
        let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_dse.json");
        match std::fs::write(&out, &json) {
            Ok(()) => println!("wrote {}", out.display()),
            Err(e) => println!("(BENCH_dse.json not written: {e})"),
        }
    }

    // --- DistillCycle training engine ---------------------------------------
    // Three layers of the distill perf story, all BENCH_MS-bounded and
    // written machine-readably to BENCH_distill.json:
    //  (a) kernel microbenches — blocked im2col core vs the retained
    //      scalar reference (conv fwd/bwd effective GFLOP/s, pack ms);
    //  (b) end-to-end ladder training — threads=0 (serial scalar
    //      reference path) vs the blocked core at 1/2/4 threads;
    //  (c) the headline blocked_vs_scalar samples/sec speedup.
    {
        use forgemorph::distill::{self, tensor, tensor_ref, DistillConfig, DistillSpec, Phase};

        // warmup once, then keep the fastest sample inside the budget
        let time_best = |f: &mut dyn FnMut()| -> f64 {
            f();
            let mut best = f64::INFINITY;
            let until = Instant::now() + budget;
            while Instant::now() < until {
                let t0 = Instant::now();
                f();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };

        // (a) per-kernel rows on a mid-size conv layer; inputs carry
        // post-ReLU sparsity, like the real hot loop
        let (kn, kh, kw, cin, cout, k) = (32usize, 8usize, 8usize, 16usize, 32usize, 3usize);
        let mut rng = Rng::new(23);
        let x: Vec<f32> = (0..kn * kh * kw * cin)
            .map(|_| ((rng.f64() * 2.0 - 1.0) as f32).max(0.0))
            .collect();
        let conv = tensor::Conv {
            w: (0..k * k * cin * cout).map(|_| (rng.f64() * 0.2 - 0.1) as f32).collect(),
            b: (0..cout).map(|_| (rng.f64() * 0.1) as f32).collect(),
            k,
            cin,
            cout,
        };
        let dpre: Vec<f32> = (0..kn * kh * kw * cout)
            .map(|_| ((rng.f64() * 2.0 - 1.0) as f32).max(0.0))
            .collect();
        let flops_fwd = (kn * kh * kw * cout) as f64 * (2 * k * k * cin) as f64;
        let flops_bwd = 2.0 * flops_fwd; // gw + dx accumulation streams

        let mut sc = tensor::Scratch::new();
        let mut out_buf = Vec::new();
        let t_fwd_blk = time_best(&mut || {
            tensor::conv_fwd_scratch(&mut sc, &x, kn, kh, kw, &conv, cin, cout, &mut out_buf);
            std::hint::black_box(out_buf.last().copied());
        });
        let t_fwd_ref = time_best(&mut || {
            std::hint::black_box(tensor_ref::conv_fwd(&x, kn, kh, kw, &conv, cin, cout));
        });
        let mut gw = vec![0.0f32; conv.w.len()];
        let mut gb = vec![0.0f32; conv.b.len()];
        let mut dx_buf = Vec::new();
        let t_bwd_blk = time_best(&mut || {
            gw.fill(0.0);
            gb.fill(0.0);
            tensor::conv_bwd_scratch(
                &mut sc,
                &x,
                kn,
                kh,
                kw,
                &conv,
                cin,
                cout,
                &dpre,
                &mut gw,
                &mut gb,
                true,
                &mut dx_buf,
            );
            std::hint::black_box(dx_buf.last().copied());
        });
        let t_bwd_ref = time_best(&mut || {
            gw.fill(0.0);
            gb.fill(0.0);
            std::hint::black_box(tensor_ref::conv_bwd(
                &x,
                kn,
                kh,
                kw,
                &conv,
                cin,
                cout,
                &dpre,
                &mut gw,
                &mut gb,
                true,
            ));
        });
        let mut col = Vec::new();
        let t_pack = time_best(&mut || {
            tensor::im2col(&x, kn, kh, kw, cin, k, &mut col);
            std::hint::black_box(col.last().copied());
        });
        let gf = |flops: f64, t: f64| flops / t / 1e9;
        let fwd_speedup = t_fwd_ref / t_fwd_blk;
        let bwd_speedup = t_bwd_ref / t_bwd_blk;
        println!(
            "conv_fwd  32x8x8 16->32: scalar {:>8.2} GFLOP/s | blocked {:>8.2} GFLOP/s ({fwd_speedup:.2}x)",
            gf(flops_fwd, t_fwd_ref),
            gf(flops_fwd, t_fwd_blk)
        );
        println!(
            "conv_bwd  32x8x8 16->32: scalar {:>8.2} GFLOP/s | blocked {:>8.2} GFLOP/s ({bwd_speedup:.2}x)",
            gf(flops_bwd, t_bwd_ref),
            gf(flops_bwd, t_bwd_blk)
        );
        println!("im2col pack 32x8x8x16 k=3:                 {}", fmt_t(t_pack));

        // (b) end-to-end ladder training, best-of-N wall time per config
        let spec = DistillSpec::tiny();
        let ds = spec.dataset(256, 64, 0);
        let reps = if budget.as_millis() < 400 { 1 } else { 3 };
        let run_cfg = |threads: usize| {
            let cfg = DistillConfig {
                epochs_per_stage: 1,
                batch: 32,
                threads,
                ..DistillConfig::default()
            };
            let mut best = f64::INFINITY;
            let mut res = None;
            for _ in 0..reps {
                let t0 = Instant::now();
                let r = distill::distillcycle_train(&spec, &ds, &cfg);
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
                res = Some(r);
            }
            (best, cfg, res.expect("trained"))
        };

        let (scalar_ms, cfg0, result) = run_cfg(0);
        let profile = distill::AccuracyProfile::from_result(&spec, &cfg0, &result);
        // teacher/student/polish records are one pass each; a calibrate
        // record summarizes epochs_per_stage passes over the train set
        let epoch_passes: usize = result
            .history
            .iter()
            .map(|r| if r.phase == Phase::Calibrate { cfg0.epochs_per_stage } else { 1 })
            .sum();
        let samples = epoch_passes * ds.n_train();
        let scalar_sps = samples as f64 / (scalar_ms / 1e3);
        println!(
            "distill::train {} ({} paths) threads=0 (scalar ref): {scalar_ms:>9.2} ms  \
             ({epoch_passes} epoch passes, {scalar_sps:.0} samples/s)",
            spec.name,
            profile.paths.len()
        );

        let mut rows = Vec::new();
        let mut best_ms = f64::INFINITY;
        let mut one_thread_ms = f64::INFINITY;
        for threads in [1usize, 2, 4] {
            let (ms, _, _) = run_cfg(threads);
            if threads == 1 {
                one_thread_ms = ms;
            }
            best_ms = best_ms.min(ms);
            let sps = samples as f64 / (ms / 1e3);
            let speedup = scalar_ms / ms;
            let scaling = one_thread_ms / ms;
            println!(
                "distill::train {} threads={threads} (blocked):        {ms:>9.2} ms  \
                 ({sps:.0} samples/s, {speedup:.2}x vs scalar, ladder scaling {scaling:.2}x)",
                spec.name
            );
            rows.push(format!(
                "    {{\"threads\": {threads}, \"wall_ms\": {ms:.3}, \
                 \"samples_per_sec\": {sps:.1}, \"speedup_vs_scalar\": {speedup:.3}, \
                 \"ladder_scaling_x\": {scaling:.3}}}"
            ));
        }
        let headline = scalar_ms / best_ms;
        let best_sps = samples as f64 / (best_ms / 1e3);
        let epoch_ms = best_ms / epoch_passes as f64;
        println!(
            "distill blocked_vs_scalar speedup: {headline:.2}x ({best_sps:.0} samples/s best)"
        );
        let json = format!(
            "{{\n  \"bench\": \"distill_engine\",\n  \"model\": \"{}\",\n  \
             \"train_samples\": {},\n  \"epochs_per_stage\": {},\n  \
             \"paths\": {},\n  \"epoch_passes\": {epoch_passes},\n  \
             \"kernels\": {{\n    \
             \"conv_fwd\": {{\"scalar_gflops\": {:.3}, \"blocked_gflops\": {:.3}, \"blocked_vs_scalar_speedup\": {fwd_speedup:.3}}},\n    \
             \"conv_bwd\": {{\"scalar_gflops\": {:.3}, \"blocked_gflops\": {:.3}, \"blocked_vs_scalar_speedup\": {bwd_speedup:.3}}},\n    \
             \"im2col_pack_ms\": {:.4}\n  }},\n  \
             \"scalar\": {{\"wall_ms\": {scalar_ms:.3}, \"samples_per_sec\": {scalar_sps:.1}}},\n  \
             \"threads\": [\n{}\n  ],\n  \
             \"wall_ms\": {best_ms:.3},\n  \"epoch_ms\": {epoch_ms:.4},\n  \
             \"samples_per_sec\": {best_sps:.1},\n  \
             \"blocked_vs_scalar_speedup\": {headline:.3},\n  \
             \"floor\": {:.6}\n}}\n",
            spec.name,
            ds.n_train(),
            cfg0.epochs_per_stage,
            profile.paths.len(),
            gf(flops_fwd, t_fwd_ref),
            gf(flops_fwd, t_fwd_blk),
            gf(flops_bwd, t_bwd_ref),
            gf(flops_bwd, t_bwd_blk),
            t_pack * 1e3,
            rows.join(",\n"),
            profile.floor()
        );
        let out =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_distill.json");
        match std::fs::write(&out, &json) {
            Ok(()) => println!("wrote {}", out.display()),
            Err(e) => println!("(BENCH_distill.json not written: {e})"),
        }
    }

    // --- cycle simulation ---------------------------------------------------
    bench("sim::simulate mnist", budget, || {
        std::hint::black_box(sim::simulate(&mnist, &cfg_m, &ZYNQ_7100, &GateMask::all_active()));
    });
    bench("sim::simulate mobilenetv2", budget, || {
        std::hint::black_box(sim::simulate(&mobilenet, &cfg_mb, &ZYNQ_7100, &GateMask::all_active()));
    });

    // --- RTL emission --------------------------------------------------------
    {
        let eval = design::evaluate(&mnist, &cfg_m, &ZYNQ_7100).unwrap();
        bench("rtl::emit mnist", budget, || {
            std::hint::black_box(rtl::emit(&mnist, &cfg_m, &eval));
        });
    }

    // --- manifest JSON parse ---------------------------------------------------
    let manifest_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        bench("json parse manifest", budget, || {
            std::hint::black_box(forgemorph::util::json::Json::parse(&text).unwrap());
        });
    }

    // --- PJRT execution (artifacts + real xla binding required) -------------
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        match forgemorph::runtime::Engine::load(&artifacts, "mnist") {
            Ok(engine) => {
                let frame = engine.frame_len();
                let mut rng = Rng::new(1);
                let x1: Vec<f32> = (0..frame).map(|_| rng.f64() as f32).collect();
                let x8: Vec<f32> = (0..8 * frame).map(|_| rng.f64() as f32).collect();
                for path in ["d1_w100", "d3_w50", "d3_w100"] {
                    bench(&format!("engine.execute {path} b=1"), budget, || {
                        std::hint::black_box(engine.execute(path, 1, &x1).unwrap());
                    });
                }
                bench("engine.execute d3_w100 b=8", budget, || {
                    std::hint::black_box(engine.execute("d3_w100", 8, &x8).unwrap());
                });
            }
            Err(e) => println!("(engine benches skipped: {e})"),
        }
    } else {
        println!("(engine benches skipped: run `make artifacts`)");
    }

    // --- power-aware trace replay (the closed-loop budget path) -------------
    // Whole-stack step-trace replay: per-frame governor observation,
    // pinned-path batching, energy integral. Reported as replayed
    // frames/sec; the decision log is deterministic, so every repeat does
    // identical work.
    {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        let frames = 512usize;
        let rate_hz = 4000.0;
        let t0 = Instant::now();
        let mut coord = Coordinator::start(
            ServeConfig { workers: 2, external_pacing: true, ..ServeConfig::default() },
            BackendSpec::sim(net, design, ZYNQ_7100, paths),
        )
        .unwrap();
        let cap = trace::default_squeeze_cap(&coord.path_energy_rows());
        let events = trace::step(frames as f64 / rate_hz, cap);
        let out = coord
            .replay_power_trace(
                &events,
                &TraceConfig { frames, rate_hz, seed: 11 },
            )
            .unwrap();
        let wall = t0.elapsed();
        println!(
            "power-trace replay mnist p=16 ({frames} frames, 2 shards): {} in {}  \
             ({:.0} frames/s, {} switches, squeeze saving {:.1}%)",
            out.answered,
            fmt_t(wall.as_secs_f64()),
            out.answered as f64 / wall.as_secs_f64(),
            out.switches.len(),
            out.squeeze_reduction_pct().unwrap_or(0.0)
        );

        // fault-path overhead: the identical replay with an armed but
        // *empty* fault plan pays the per-frame injector bookkeeping
        // (scrub pass, directive lookup, capacity feed) without any
        // fault actually striking — the pure cost of the machinery
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        let t0 = Instant::now();
        let mut coord = Coordinator::start(
            ServeConfig { workers: 2, external_pacing: true, ..ServeConfig::default() },
            BackendSpec::sim(net, design, ZYNQ_7100, paths),
        )
        .unwrap();
        let idle_plan = FaultPlan::empty(11);
        let out_idle = coord
            .replay_trace(&events, &TraceConfig { frames, rate_hz, seed: 11 }, Some(&idle_plan))
            .unwrap();
        let wall_idle = t0.elapsed();
        assert_eq!(out_idle.answered, out.answered, "idle injector changed the replay");
        let disabled_ms = wall.as_secs_f64() * 1e3;
        let idle_ms = wall_idle.as_secs_f64() * 1e3;
        let overhead_pct = (idle_ms - disabled_ms) / disabled_ms * 100.0;
        println!(
            "fault-injection idle overhead ({frames} frames): disabled {disabled_ms:.2} ms, \
             idle injector {idle_ms:.2} ms ({overhead_pct:+.1}%)"
        );
        // fold the row into the bench trajectory file the distill section
        // wrote earlier this run (absolute _ms/_pct keys: informational
        // under bench-check, gated only with --absolute)
        let bench_json =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_distill.json");
        if let Ok(text) = std::fs::read_to_string(&bench_json) {
            if let Some(body) = text.trim_end().strip_suffix('}') {
                let patched = format!(
                    "{body}  ,\n  \"fault_overhead\": {{\"disabled_ms\": {disabled_ms:.3}, \
                     \"idle_injector_ms\": {idle_ms:.3}, \
                     \"overhead_pct\": {overhead_pct:.2}}}\n}}\n"
                );
                match std::fs::write(&bench_json, patched) {
                    Ok(()) => println!("appended fault_overhead to {}", bench_json.display()),
                    Err(e) => println!("(fault_overhead not appended: {e})"),
                }
            }
        }

        // tracing overhead: the identical replay with an attached
        // TraceSink vs none — the pure cost of stamping ~3 entries per
        // frame onto the lane-0 ring. Best-of-3 each way; within_2pct
        // is the gated row (it measures the recorder, not the host)
        let run_replay = |sink: Option<std::sync::Arc<forgemorph::obs::TraceSink>>| {
            let net = zoo::mnist();
            let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
            let paths = morph::depth_ladder(&net);
            let mut coord = Coordinator::start(
                ServeConfig {
                    workers: 2,
                    external_pacing: true,
                    trace: sink,
                    ..ServeConfig::default()
                },
                BackendSpec::sim(net, design, ZYNQ_7100, paths),
            )
            .unwrap();
            let t0 = Instant::now();
            let traced = coord
                .replay_power_trace(&events, &TraceConfig { frames, rate_hz, seed: 11 })
                .unwrap();
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            assert_eq!(traced.answered, out.answered, "tracing changed the replay");
            ms
        };
        let best = |with_sink: bool| {
            (0..3)
                .map(|_| run_replay(with_sink.then(forgemorph::obs::TraceSink::shared)))
                .fold(f64::INFINITY, f64::min)
        };
        let off_ms = best(false);
        let on_ms = best(true);
        let trace_pct = (on_ms - off_ms) / off_ms * 100.0;
        let within = trace_pct <= 2.0;
        println!(
            "tracing overhead ({frames} frames): sink off {off_ms:.2} ms, sink on \
             {on_ms:.2} ms ({trace_pct:+.1}%, within_2pct: {within})"
        );
        if let Ok(text) = std::fs::read_to_string(&bench_json) {
            if let Some(body) = text.trim_end().strip_suffix('}') {
                let patched = format!(
                    "{body}  ,\n  \"trace_overhead\": {{\"off_ms\": {off_ms:.3}, \
                     \"on_ms\": {on_ms:.3}, \"overhead_pct\": {trace_pct:.2}, \
                     \"within_2pct\": {within}}}\n}}\n"
                );
                match std::fs::write(&bench_json, patched) {
                    Ok(()) => println!("appended trace_overhead to {}", bench_json.display()),
                    Err(e) => println!("(trace_overhead not appended: {e})"),
                }
            }
        }
    }

    // --- surrogate classifier: packed batch pass vs scalar per-frame dots ---
    // The serving-numerics kernel on its own: one packed pass over the
    // batch (reused output buffer, nothing allocated per frame) against
    // the retained scalar reference. Both produce bit-identical logits.
    {
        use forgemorph::backend::SurrogateClassifier;
        let net = zoo::cifar10();
        let (h, w, c) = net.input_dims();
        let frame_len = h * w * c;
        let paths = morph::depth_ladder(&net);
        let clf = SurrogateClassifier::new(frame_len, 10, &paths);
        let mut rng = Rng::new(7);
        let batch = 8usize;
        let input: Vec<f32> = (0..batch * frame_len).map(|_| rng.f64() as f32).collect();
        let mut out = Vec::new();
        bench("surrogate logits b=8 batched (packed pass)", budget, || {
            clf.batch_logits_into("d3_w100", batch, &input, &mut out).unwrap();
            std::hint::black_box(out.last().copied());
        });
        bench("surrogate logits b=8 scalar (per-frame)", budget, || {
            for f in 0..batch {
                let frame = &input[f * frame_len..(f + 1) * frame_len];
                std::hint::black_box(clf.scalar_logits("d3_w100", frame).unwrap());
            }
        });
    }

    // --- sharded serving throughput (sim backend, no artifacts needed) ------
    // Floods the coordinator and measures sustained requests/sec at 1, 2
    // and 4 worker shards. Each executed batch walks the cycle simulator
    // (fidelity 4 replays per batch) and runs the packed surrogate pass,
    // so the work is CPU-bound and the scaling curve reflects real shard
    // parallelism. Acceptance target: >= 2x req/s at 4 workers vs 1.
    {
        let net = zoo::cifar10();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        let (h, w, c) = net.input_dims();
        let frame_len = h * w * c;
        let mut rng = Rng::new(11);
        let frames: Vec<Vec<f32>> = (0..32)
            .map(|_| (0..frame_len).map(|_| rng.f64() as f32).collect())
            .collect();
        let n_requests = 1536usize;
        let mut base_rps = 0.0f64;
        for workers in [1usize, 2, 4] {
            let spec = BackendSpec::Sim {
                net: net.clone(),
                design: design.clone(),
                device: ZYNQ_7100,
                paths: paths.clone(),
                batches: vec![1, 8],
                fidelity: 4,
            };
            let cfg = ServeConfig {
                max_wait: Duration::from_micros(500),
                patience: 2,
                workers,
                ..ServeConfig::default()
            };
            let t0 = Instant::now();
            let mut coord = Coordinator::start(cfg, spec).unwrap();
            let startup = t0.elapsed();
            // warmup
            let warm: Vec<_> = (0..64)
                .map(|i| coord.submit(frames[i % frames.len()].clone()).unwrap())
                .collect();
            for rx in warm {
                rx.recv().unwrap();
            }
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..n_requests)
                .map(|i| coord.submit(frames[i % frames.len()].clone()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            let wall = t0.elapsed();
            let metrics = coord.shutdown();
            let rps = n_requests as f64 / wall.as_secs_f64();
            if workers == 1 {
                base_rps = rps;
            }
            println!(
                "serving throughput (sim) workers={workers}: {rps:>9.0} req/s \
                 ({:.2}x vs 1 worker) | startup {} | {} batches, mean batch {:.2}",
                rps / base_rps.max(1.0),
                fmt_t(startup.as_secs_f64()),
                metrics.batches,
                metrics.requests as f64 / metrics.batches.max(1) as f64
            );
        }
    }
}
