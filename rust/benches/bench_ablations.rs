//! `cargo bench` harness #3: ablations over the design choices DESIGN.md
//! calls out.
//!
//! * MOGA population / generation scaling (Sec. III-C: "deeper networks
//!   are evaluated with larger populations")
//! * MOGA vs the roofline-allocation heuristic (the conventional DSE)
//! * governor hysteresis (patience) vs switch thrash on a noisy budget
//! * batching deadline vs throughput/latency trade
//! * morph schedule extraction (max_paths sweep)
//! * device portability sweep (same model, four parts)

use std::time::Duration;

use forgemorph::coordinator::trace;
use forgemorph::coordinator::BatchPolicy;
use forgemorph::design::{self, DesignConfig};
use forgemorph::dse::{self, roofline};
use forgemorph::graph::zoo;
use forgemorph::morph::governor::{Budget, Decision, Governor, PathCosts};
use forgemorph::morph::{schedule, MorphPath, PathRegistry};
use forgemorph::pe::{FpRep, DEVICES, ZYNQ_7100};
use forgemorph::sim::{self, GateMask};
use forgemorph::util::rng::Rng;

fn main() {
    println!("=== bench_ablations ===");
    moga_scaling();
    moga_vs_roofline();
    governor_hysteresis();
    batching_deadline();
    schedule_extraction();
    device_portability();
}

fn moga_scaling() {
    println!("\n-- MOGA population/generation scaling (CIFAR-10) --");
    println!("{:<22} {:>10} {:>12} {:>12} {:>9}", "config", "evals", "best ms", "front", "seconds");
    let net = zoo::cifar10();
    for (pop, gens) in [(16, 10), (32, 20), (64, 40), (128, 60)] {
        let t0 = std::time::Instant::now();
        let res = dse::run(
            &net,
            &ZYNQ_7100,
            &dse::DseConfig {
                population: pop,
                generations: gens,
                seed: 3,
                constraints: dse::Constraints::device(&ZYNQ_7100),
                ..dse::DseConfig::default()
            },
        );
        println!(
            "{:<22} {:>10} {:>12.4} {:>12} {:>9.2}",
            format!("pop={pop} gens={gens}"),
            res.evaluations,
            res.best_latency_per_gen.last().unwrap(),
            res.pareto.len(),
            t0.elapsed().as_secs_f64()
        );
    }
}

fn moga_vs_roofline() {
    println!("\n-- MOGA front vs roofline heuristic --");
    println!("{:<12} {:>14} {:>10} | {:>14} {:>10}", "model", "RLM ms", "RLM DSP", "MOGA ms", "MOGA DSP");
    for name in ["mnist", "svhn", "cifar10"] {
        let net = zoo::by_name(name).unwrap();
        let rl_cfg = roofline::roofline_allocate(&net, &ZYNQ_7100, FpRep::Int16);
        let rl = design::evaluate(&net, &rl_cfg, &ZYNQ_7100).unwrap();
        let res = dse::run(
            &net,
            &ZYNQ_7100,
            &dse::DseConfig {
                population: 64,
                generations: 30,
                seed: 4,
                constraints: dse::Constraints {
                    dsp: Some(rl.resources.dsp), // same area budget
                    ..dse::Constraints::none()
                },
                ..dse::DseConfig::default()
            },
        );
        let best = res
            .pareto
            .iter()
            .map(|c| c.objectives.latency_ms)
            .fold(f64::INFINITY, f64::min);
        println!(
            "{:<12} {:>14.4} {:>10} | {:>14.4} {:>10}",
            name,
            rl.latency_ms(),
            rl.resources.dsp,
            best,
            res.pareto
                .iter()
                .min_by(|a, b| a.objectives.latency_ms.partial_cmp(&b.objectives.latency_ms).unwrap())
                .map(|c| c.objectives.dsp)
                .unwrap_or(0)
        );
    }
}

fn sample_registry() -> (PathRegistry, PathCosts) {
    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
    let paths: Vec<MorphPath> = (1..=3)
        .map(|d| MorphPath {
            name: format!("d{d}_w100"),
            depth: d,
            width_pct: 100,
            accuracy: 0.9 + d as f64 * 0.03,
            params: d * 1000,
            macs: d * 100_000,
        })
        .collect();
    let registry = PathRegistry::new(paths);
    let costs = forgemorph::coordinator::sim_path_costs(&net, &design, &ZYNQ_7100, &registry)
        .expect("lowerable morph paths");
    (registry, costs)
}

fn governor_hysteresis() {
    println!("\n-- governor patience vs switch thrash (noisy budget, 500 steps) --");
    println!("{:<12} {:>10} {:>14}", "patience", "switches", "time-on-target");
    let (_, costs) = sample_registry();
    let full_power = costs.rows.iter().map(|r| r.1).fold(0.0, f64::max);
    for patience in [1usize, 2, 4, 8] {
        let (registry, costs) = sample_registry();
        let mut gov = Governor::new(registry, costs, patience);
        let mut rng = Rng::new(5);
        let mut switches = 0u64;
        let mut on_target = 0u64;
        for step in 0..500 {
            // noisy budget around the squeeze threshold
            let base = if (step / 100) % 2 == 0 { full_power + 50.0 } else { full_power - 45.0 };
            let noisy = base + rng.gauss() * 25.0;
            match gov.observe(&Budget { power_mw: Some(noisy), latency_ms: None }) {
                Decision::Switch { .. } => switches += 1,
                Decision::Hold => {}
            }
            let want_full = base > full_power;
            if (gov.current() == "d3_w100") == want_full {
                on_target += 1;
            }
        }
        println!("{:<12} {:>10} {:>13.1}%", patience, switches, on_target as f64 / 5.0);
    }
}

fn batching_deadline() {
    println!("\n-- batching deadline: offered load 2000 Hz, sizes {{1,8}} --");
    println!("{:<14} {:>10} {:>14} {:>14}", "max_wait", "batches", "mean batch", "mean queue ms");
    let arrivals = trace::arrivals(trace::ArrivalPattern::Poisson { rate_hz: 2000.0 }, 2000, 6);
    for wait_ms in [0.5f64, 1.0, 2.0, 5.0, 10.0] {
        let policy = BatchPolicy::new(vec![1, 8], Duration::from_secs_f64(wait_ms / 1e3));
        // discrete-event replay: service is instantaneous, so the queue
        // dynamics isolate the batching policy itself
        let mut pending: Vec<f64> = Vec::new();
        let mut batches = 0u64;
        let mut frames = 0u64;
        let mut queue_time = 0.0f64;
        let mut i = 0usize;
        let mut t = 0.0f64;
        let dt = 1e-4;
        while i < arrivals.len() || !pending.is_empty() {
            while i < arrivals.len() && arrivals[i] <= t {
                pending.push(arrivals[i]);
                i += 1;
            }
            let oldest_wait = pending.first().map(|&a| t - a).unwrap_or(0.0);
            let fire = pending.len() >= policy.max_size()
                || (!pending.is_empty() && oldest_wait >= wait_ms / 1e3);
            if fire {
                let n = policy.fit(pending.len()).min(pending.len());
                for &a in &pending[..n] {
                    queue_time += t - a;
                }
                pending.drain(..n);
                batches += 1;
                frames += n as u64;
            }
            t += dt;
        }
        println!(
            "{:<14} {:>10} {:>14.2} {:>14.3}",
            format!("{wait_ms} ms"),
            batches,
            frames as f64 / batches.max(1) as f64,
            queue_time / frames.max(1) as f64 * 1e3
        );
    }
}

fn schedule_extraction() {
    println!("\n-- morph schedule extraction: candidate lattice -> deployed set --");
    let net = zoo::cifar10();
    let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
    // full (depth x width) lattice as candidates
    let mut cands = Vec::new();
    let n = net.conv_layer_ids().len();
    for depth in 1..=n {
        for width in [100usize, 50] {
            let mask = if width < 100 {
                GateMask::width(0.5)
            } else if depth < n {
                GateMask::depth_prefix(&net, depth)
            } else {
                GateMask::all_active()
            };
            let r = sim::simulate(&net, &design, &ZYNQ_7100, &mask);
            cands.push(schedule::Candidate {
                path: MorphPath {
                    name: format!("d{depth}_w{width}"),
                    depth,
                    width_pct: width,
                    accuracy: 0.55 + 0.08 * depth as f64 - if width < 100 { 0.05 } else { 0.0 },
                    params: 0,
                    macs: depth * width,
                },
                latency_ms: r.latency_ms(),
                power_mw: r.power_mw,
            });
        }
    }
    println!("lattice: {} candidates", cands.len());
    for max_paths in [2usize, 3, 4] {
        let sel = schedule::extract(
            cands.clone(),
            &schedule::ScheduleSpec { min_accuracy: 0.6, max_paths },
        );
        let names: Vec<String> = sel
            .iter()
            .map(|c| format!("{}({:.2}ms)", c.path.name, c.latency_ms))
            .collect();
        println!("  max_paths={max_paths}: {}", names.join(" "));
    }
}

fn device_portability() {
    println!("\n-- portability: MNIST balanced mapping across parts --");
    println!("{:<12} {:>8} {:>10} {:>12} {:>10}", "device", "DSP", "FPS", "latency ms", "power mW");
    let net = zoo::mnist();
    for dev in DEVICES {
        let cfg = DesignConfig::balanced(&net, FpRep::Int16, dev);
        let r = sim::simulate(&net, &cfg, dev, &GateMask::all_active());
        let eval = design::evaluate(&net, &cfg, dev).unwrap();
        println!(
            "{:<12} {:>8} {:>10.0} {:>12.4} {:>10.0}",
            dev.name,
            eval.resources.dsp,
            r.fps(),
            r.latency_ms(),
            r.power_mw
        );
    }
}
