//! NeuroForge DSE deep-dive (the Fig. 2 experiment, interactively).
//!
//! Runs the MOGA on the CIFAR-10 benchmark under several constraint
//! regimes, prints the Pareto fronts, convergence telemetry and an ASCII
//! rendering of the latency-vs-DSP trade-off, and cross-checks three
//! front points against the cycle simulator (the Fig. 10 validation).
//!
//! ```bash
//! cargo run --release --example dse_explore [-- --pop 96 --gens 40]
//! ```

use anyhow::Result;
use forgemorph::dse;
use forgemorph::graph::zoo;
use forgemorph::pe::ZYNQ_7100;
use forgemorph::sim::{self, GateMask};
use forgemorph::util::cli::Args;

fn ascii_scatter(points: &[(f64, usize)], front: &[(f64, usize)]) -> String {
    const W: usize = 64;
    const H: usize = 18;
    let (mut lo_x, mut hi_x) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut lo_y, mut hi_y) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(lat, dsp) in points {
        lo_x = lo_x.min(lat.log10());
        hi_x = hi_x.max(lat.log10());
        lo_y = lo_y.min((dsp.max(1) as f64).log10());
        hi_y = hi_y.max((dsp.max(1) as f64).log10());
    }
    let mut grid = vec![vec![b' '; W]; H];
    let place = |grid: &mut Vec<Vec<u8>>, lat: f64, dsp: usize, ch: u8| {
        let x = ((lat.log10() - lo_x) / (hi_x - lo_x + 1e-12) * (W - 1) as f64) as usize;
        let y = (((dsp.max(1) as f64).log10() - lo_y) / (hi_y - lo_y + 1e-12)
            * (H - 1) as f64) as usize;
        grid[H - 1 - y][x] = ch;
    };
    for &(lat, dsp) in points {
        place(&mut grid, lat, dsp, b'.');
    }
    for &(lat, dsp) in front {
        place(&mut grid, lat, dsp, b'#');
    }
    let mut s = String::new();
    s.push_str("  DSP (log)\n");
    for row in grid {
        s.push_str("  |");
        s.push_str(std::str::from_utf8(&row).unwrap());
        s.push('\n');
    }
    s.push_str("  +");
    s.push_str(&"-".repeat(W));
    s.push_str("> latency (log)\n  ('.' evaluated, '#' Pareto front)\n");
    s
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let net = zoo::by_name(args.get_or("model", "cifar10")).expect("zoo model");
    let pop = args.get_usize("pop", 96);
    let gens = args.get_usize("gens", 40);

    println!("== NeuroForge DSE on {} ==", net.name);
    for (label, constraints) in [
        ("unconstrained", dse::Constraints::none()),
        ("device budget (Zynq-7100)", dse::Constraints::device(&ZYNQ_7100)),
        (
            "tight: <=600 DSP, <=1 ms",
            dse::Constraints {
                latency_ms: Some(1.0),
                dsp: Some(600),
                lut: None,
                bram: None,
                power_mw: None,
            },
        ),
    ] {
        let cfg = dse::DseConfig {
            population: pop,
            generations: gens,
            seed: 3,
            constraints,
            ..dse::DseConfig::default()
        };
        let t0 = std::time::Instant::now();
        let res = dse::run(&net, &ZYNQ_7100, &cfg);
        println!(
            "\n-- {label}: {} evals in {:.2}s, front {} points --",
            res.evaluations,
            t0.elapsed().as_secs_f64(),
            res.pareto.len()
        );
        for c in res.pareto.iter().take(12) {
            println!(
                "  p={:<22} {:>6} DSP {:>10.4} ms {:>9} LUT",
                format!("{:?}", c.config.parallelism),
                c.objectives.dsp,
                c.objectives.latency_ms,
                c.objectives.lut
            );
        }
        if label == "device budget (Zynq-7100)" {
            let front: Vec<(f64, usize)> = res
                .pareto
                .iter()
                .map(|c| (c.objectives.latency_ms, c.objectives.dsp))
                .collect();
            println!("{}", ascii_scatter(&res.evaluated, &front));

            // estimator-vs-simulator cross-check on three front points
            println!("  est-vs-sim cross-check (Fig. 10 shape):");
            let picks = [0, res.pareto.len() / 2, res.pareto.len() - 1];
            for &i in &picks {
                let c = &res.pareto[i];
                let r = sim::simulate(&net, &c.config, &ZYNQ_7100, &GateMask::all_active());
                println!(
                    "    p={:<22} est {:>9.4} ms | sim {:>9.4} ms ({:+.1}%)",
                    format!("{:?}", c.config.parallelism),
                    c.objectives.latency_ms,
                    r.latency_ms(),
                    (r.latency_ms() / c.objectives.latency_ms - 1.0) * 100.0
                );
            }
        }
    }

    // MOGA convergence: larger populations explore better (Sec. III-C)
    println!("\n-- population ablation (best latency after {gens} gens) --");
    for p in [16, 32, 64, 128] {
        let cfg = dse::DseConfig {
            population: p,
            generations: gens,
            seed: 11,
            constraints: dse::Constraints::device(&ZYNQ_7100),
            ..dse::DseConfig::default()
        };
        let res = dse::run(&net, &ZYNQ_7100, &cfg);
        println!(
            "  pop {:>4}: best latency {:.4} ms, front {} points",
            p,
            res.best_latency_per_gen.last().unwrap(),
            res.pareto.len()
        );
    }
    Ok(())
}
