//! Quickstart: the whole ForgeMorph compile path in ~60 lines.
//!
//! Parses a CNN descriptor, explores the design space with NeuroForge,
//! emits Verilog for a Pareto point, and cycle-simulates it — no AOT
//! artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use forgemorph::design;
use forgemorph::dse;
use forgemorph::graph::parser;
use forgemorph::pe::ZYNQ_7100;
use forgemorph::rtl;
use forgemorph::sim::{self, GateMask};

const MODEL: &str = r#"{
  "name": "quickstart-8-16",
  "input": [28, 28, 1],
  "layers": [
    {"type": "conv", "filters": 8, "k": 3},
    {"type": "maxpool", "k": 2},
    {"type": "conv", "filters": 16, "k": 3},
    {"type": "maxpool", "k": 2},
    {"type": "fc", "out": 10}
  ]
}"#;

fn main() -> Result<()> {
    // 1. parse the high-level model description
    let net = parser::parse(MODEL)?;
    println!(
        "parsed '{}': {} layers, {} params, {} MACs/frame",
        net.name,
        net.layers.len(),
        net.count_params()?,
        net.count_macs()?
    );

    // 2. NeuroForge: multi-objective DSE under the Zynq-7100 budget
    let cfg = dse::DseConfig {
        population: 48,
        generations: 16,
        seed: 1,
        constraints: dse::Constraints::device(&ZYNQ_7100),
        ..dse::DseConfig::default()
    };
    let result = dse::run(&net, &ZYNQ_7100, &cfg);
    println!("\nPareto front ({} candidates evaluated):", result.evaluations);
    for c in &result.pareto {
        println!(
            "  p={:<10} {:>6} DSP  {:>9.4} ms",
            format!("{:?}", c.config.parallelism),
            c.objectives.dsp,
            c.objectives.latency_ms
        );
    }

    // 3. pick the fastest feasible point and emit its RTL
    let best = &result.pareto[0];
    let eval = design::evaluate(&net, &best.config, &ZYNQ_7100)?;
    let bundle = rtl::emit(&net, &best.config, &eval);
    println!(
        "\nemitted {} Verilog files ({} bytes), top = {}",
        bundle.files.len(),
        bundle.total_bytes(),
        bundle.top_name
    );

    // 4. cycle-simulate it — full pipeline and a NeuroMorph depth morph
    let full = sim::simulate(&net, &best.config, &ZYNQ_7100, &GateMask::all_active());
    let d1 = sim::simulate(&net, &best.config, &ZYNQ_7100, &GateMask::depth_prefix(&net, 1));
    println!(
        "\nsimulated: full {:.4} ms @ {:.0} mW | depth-1 morph {:.4} ms @ {:.0} mW ({:.2}x faster)",
        full.latency_ms(),
        full.power_mw,
        d1.latency_ms(),
        d1.power_mw,
        full.latency_ms() / d1.latency_ms()
    );
    Ok(())
}
