//! RTL back-end demo: emit and inspect the Verilog for a design point.
//!
//! Emits the full bundle for the MNIST benchmark at two different design
//! points (small and large), prints the module inventory with per-stage
//! PE allocations, and diffs the resource estimates.
//!
//! ```bash
//! cargo run --release --example rtl_emit [-- --model mnist --out rtl_out]
//! ```

use anyhow::Result;
use forgemorph::design::{self, DesignConfig};
use forgemorph::graph::zoo;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::rtl;
use forgemorph::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let net = zoo::by_name(args.get_or("model", "mnist")).expect("zoo model");
    let out_root = std::path::PathBuf::from(args.get_or("out", "rtl_out"));

    for (label, p) in [("small", 1usize), ("large", 8)] {
        let cfg = DesignConfig::uniform(&net, p, FpRep::Int16);
        let eval = design::evaluate(&net, &cfg, &ZYNQ_7100)?;
        let bundle = rtl::emit(&net, &cfg, &eval);
        let dir = out_root.join(label);
        bundle.write_to(&dir)?;

        println!("== {label} design (uniform p={p}) ==");
        println!(
            "  {} DSP, {} LUT, {} BRAM — est. {:.4} ms @ {} MHz",
            eval.resources.dsp,
            eval.resources.lut,
            eval.resources.bram,
            eval.latency_ms(),
            eval.clock_mhz
        );
        for (name, src) in &bundle.files {
            println!("  {:<28} {:>7} bytes {:>3} modules", name, src.len(), src.matches("endmodule").count());
        }
        println!("  wrote to {}", dir.display());
        let top = bundle.file(&format!("{}.v", bundle.top_name)).unwrap();
        let stages = top.lines().filter(|l| l.contains("// stage")).count();
        println!("  top module chains {stages} pipeline stages\n");
    }
    Ok(())
}
