//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! * L1/L2 (already ran at `make artifacts`): DistillCycle-trained
//!   morphable CNN, Pallas kernels, per-path HLO artifacts.
//! * L3 (this process): builds an `InferenceBackend` per worker shard —
//!   PJRT over the AOT artifacts when they exist (after numeric
//!   verification against golden probe logits), otherwise the
//!   self-contained cycle-simulation backend — then serves a Poisson
//!   stream of classification requests through the sharded coordinator
//!   while a power budget trace squeezes and releases the NeuroMorph
//!   governor.
//!
//! Reported: throughput, batch stats, queue/exec/e2e latency, morph
//! switches, per-path frame counts, modeled FPGA energy. Recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example adaptive_serving -- --workers 4
//! # or, with trained artifacts and a real xla binding:
//! make artifacts && cargo run --release --example adaptive_serving
//! ```

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};
use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{Coordinator, ServeConfig};
use forgemorph::design::DesignConfig;
use forgemorph::graph::zoo;
use forgemorph::morph;
use forgemorph::morph::governor::Budget;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::runtime::Engine;
use forgemorph::sim::{self, GateMask};
use forgemorph::util::cli::Args;
use forgemorph::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n_requests = args.get_usize("requests", 480);
    let rate_hz = args.get_f64("rate", 3000.0);
    let workers = args.get_usize("workers", 2);
    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, args.get_usize("p", 4), FpRep::Int16);
    let have_artifacts = artifacts.join("manifest.json").exists();

    // ---- phase 0: verify the AOT artifacts numerically -----------------
    let spec = if have_artifacts {
        println!("== phase 0: artifact verification ==");
        let engine = Engine::load(&artifacts, "mnist").context("engine load")?;
        println!("PJRT platform: {}", engine.platform());
        for (path, err) in engine.verify_probe()? {
            ensure!(err < 1e-3, "path {path} deviates: {err}");
            println!("  {path}: max|err| vs golden = {err:.2e}  OK");
        }
        let paths = engine.model().morph_paths();
        println!("morph paths (DistillCycle accuracies on synthetic MNIST):");
        for p in &paths {
            println!(
                "  {:<8} depth {} width {:>3}%  acc {:.3}  {:>7} params {:>9} MACs",
                p.name, p.depth, p.width_pct, p.accuracy, p.params, p.macs
            );
        }
        drop(engine); // each coordinator shard owns its own engine
        BackendSpec::Pjrt {
            artifacts_dir: artifacts,
            model: "mnist".into(),
            net: net.clone(),
            design: design.clone(),
            device: ZYNQ_7100,
        }
    } else {
        println!("== phase 0: no artifacts — using the cycle-simulation backend ==");
        let paths = morph::depth_ladder(&net);
        for p in &paths {
            println!(
                "  {:<8} depth {}  acc {:.3}  {:>7} params {:>9} MACs",
                p.name, p.depth, p.accuracy, p.params, p.macs
            );
        }
        BackendSpec::sim(net.clone(), design.clone(), ZYNQ_7100, paths)
    };

    // ---- phase 1: FPGA-side cost table ---------------------------------
    println!("\n== phase 1: simulated FPGA costs per morph path ==");
    let full = sim::simulate(&net, &design, &ZYNQ_7100, &GateMask::all_active());
    println!(
        "  design p=4: full path {:.4} ms, {:.0} mW, {:.2} uJ/frame",
        full.latency_ms(),
        full.power_mw,
        full.energy_per_frame_j() * 1e6
    );
    for depth in 1..net.conv_layer_ids().len() {
        let r = sim::simulate(&net, &design, &ZYNQ_7100, &GateMask::depth_prefix(&net, depth));
        println!(
            "  depth-{depth} morph: {:.4} ms ({:.2}x), {:.0} mW ({:.0}% dyn. saving)",
            r.latency_ms(),
            full.latency_ms() / r.latency_ms(),
            r.power_mw,
            (1.0 - (r.power_mw - 455.0).max(0.0) / (full.power_mw - 455.0).max(1.0)) * 100.0
        );
    }

    // ---- phase 2: adaptive serving under a budget trace ----------------
    println!(
        "\n== phase 2: serving {n_requests} Poisson requests @ ~{rate_hz} Hz \
         on {workers} worker shard(s) =="
    );
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(2),
        patience: 2,
        workers,
        ..ServeConfig::default()
    };
    let mut coord = Coordinator::start(cfg, spec)?;

    // squeeze below the full path's simulated draw but above the lightest
    // path's, so the governor has a feasible downshift target
    let squeeze_mw = full.power_mw - 40.0;

    let mut rng = Rng::new(2024);
    let mut receivers = Vec::with_capacity(n_requests);
    let t0 = Instant::now();
    let third = n_requests / 3;
    for i in 0..n_requests {
        if i == third {
            println!(
                "  [t={:.2}s] power budget -> {squeeze_mw:.0} mW (squeeze)",
                t0.elapsed().as_secs_f64()
            );
            coord.set_budget(Budget { power_mw: Some(squeeze_mw), latency_ms: None })?;
        }
        if i == 2 * third {
            println!(
                "  [t={:.2}s] power budget -> unconstrained (release)",
                t0.elapsed().as_secs_f64()
            );
            coord.set_budget(Budget::unconstrained())?;
        }
        let frame: Vec<f32> = (0..784).map(|_| rng.f64() as f32).collect();
        receivers.push((i, coord.submit(frame).context("submit")?));
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate_hz).min(0.01)));
    }

    let mut by_path = std::collections::BTreeMap::<String, u64>::new();
    let mut by_shard = std::collections::BTreeMap::<usize, u64>::new();
    let mut phase_paths = vec![std::collections::BTreeSet::new(); 3];
    let mut answered = 0usize;
    for (i, rx) in receivers {
        let resp = rx.recv_timeout(Duration::from_secs(60)).context("response")?;
        *by_path.entry(resp.path.clone()).or_insert(0) += 1;
        *by_shard.entry(resp.shard).or_insert(0) += 1;
        phase_paths[(i / third.max(1)).min(2)].insert(resp.path);
        answered += 1;
    }
    let wall = t0.elapsed();
    let metrics = coord.shutdown();

    println!("\n== results ==");
    println!(
        "  {} requests in {:.2}s -> {:.1} req/s sustained ({} batches, mean batch {:.2})",
        answered,
        wall.as_secs_f64(),
        metrics.throughput_fps(wall),
        metrics.batches,
        metrics.requests as f64 / metrics.batches.max(1) as f64
    );
    println!(
        "  latency: queue mean {:.2} ms | exec mean {:.2} ms | e2e mean {:.2} ms, p99 {:.2} ms, max {:.2} ms",
        metrics.queue_latency.mean_us() / 1e3,
        metrics.exec_latency.mean_us() / 1e3,
        metrics.e2e_latency.mean_us() / 1e3,
        metrics.e2e_latency.quantile_us(0.99) as f64 / 1e3,
        metrics.e2e_latency.max_us() as f64 / 1e3
    );
    println!(
        "  morph switches: {} (stall frames {}) | modeled FPGA energy: {:.4} J",
        metrics.morph_switches, metrics.stall_frames, metrics.energy_j
    );
    for (path, n) in &by_path {
        println!("  path {path}: {n} frames");
    }
    for (shard, n) in &by_shard {
        println!("  shard {shard}: {n} frames");
    }
    println!("  phase path sets: {:?}", phase_paths);

    ensure!(answered == n_requests, "dropped requests");
    ensure!(metrics.morph_switches >= 2, "governor never morphed");
    ensure!(by_path.len() >= 2, "only one path used — squeeze had no effect");
    println!("\nadaptive serving demo PASSED");
    Ok(())
}
