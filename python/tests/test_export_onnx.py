"""ONNX corpus writer: wire-format validity, determinism, zoo coverage.

The authoritative round-trip check lives on the Rust side (CI imports
every corpus file via ``graph dump --onnx`` and diffs the StagePlan JSON
against the hand-built zoo twin); these tests pin the Python half in
isolation with a minimal in-test wire walker — no ``onnx`` dependency.
"""

from compile import export_onnx as ex


# -- minimal protobuf wire walker (test-local, decode side of ex._uv) -------


def read_varint(buf: bytes, pos: int) -> tuple[int, int]:
    shift = val = 0
    while True:
        b = buf[pos]
        val |= (b & 0x7F) << shift
        pos += 1
        if not b & 0x80:
            return val, pos
        shift += 7


def fields(buf: bytes):
    """Yield (field_number, wire_type, payload) triples."""
    pos = 0
    while pos < len(buf):
        key, pos = read_varint(buf, pos)
        field, wire = key >> 3, key & 7
        if wire == 0:
            val, pos = read_varint(buf, pos)
            yield field, wire, val
        elif wire == 2:
            ln, pos = read_varint(buf, pos)
            yield field, wire, buf[pos:pos + ln]
            pos += ln
        else:  # the exporter only emits wire types 0 and 2
            raise AssertionError(f"unexpected wire type {wire}")


def graph_of(model_bytes: bytes) -> bytes:
    for field, _, payload in fields(model_bytes):
        if field == 7:
            return payload
    raise AssertionError("no GraphProto in model")


def nodes_of(graph: bytes) -> list[dict]:
    out = []
    for field, _, payload in fields(graph):
        if field != 1:
            continue
        n = {"inputs": [], "outputs": [], "op": "", "name": ""}
        for f2, _, p2 in fields(payload):
            if f2 == 1:
                n["inputs"].append(p2.decode())
            elif f2 == 2:
                n["outputs"].append(p2.decode())
            elif f2 == 3:
                n["name"] = p2.decode()
            elif f2 == 4:
                n["op"] = p2.decode()
        out.append(n)
    return out


# -- tests ------------------------------------------------------------------


def test_every_zoo_model_emits_wire_parseable_bytes():
    for key, build in ex.MODELS.items():
        data = ex.emit(build())
        top = {f for f, _, _ in fields(data)}
        # ir_version, producer, version, graph, opset
        assert top == {1, 2, 3, 7, 8}, key
        assert nodes_of(graph_of(data)), f"{key}: no nodes"


def test_emission_is_deterministic():
    for build in (ex.mnist, ex.yolov5l):
        assert ex.emit(build()) == ex.emit(build())


def test_mnist_node_inventory():
    nodes = nodes_of(graph_of(ex.emit(ex.mnist())))
    # 3x (Conv+Relu), 3x MaxPool, Flatten+Gemm, Softmax = 12 nodes
    ops = [n["op"] for n in nodes]
    assert len(ops) == 12
    assert ops.count("Conv") == 3 and ops.count("Relu") == 3
    assert ops.count("MaxPool") == 3
    assert ops[-3:] == ["Flatten", "Gemm", "Softmax"]
    # fused relu is split: Conv writes t{id}c, Relu folds it back to t{id}
    assert nodes[0]["outputs"] == ["t1c"]
    assert nodes[1]["op"] == "Relu" and nodes[1]["outputs"] == ["t1"]


def test_conv_emits_auto_pad_never_pads():
    graph = graph_of(ex.emit(ex.resnet50()))
    for n_field, _, payload in fields(graph):
        if n_field != 1:
            continue
        attrs = {}
        op = ""
        for f2, _, p2 in fields(payload):
            if f2 == 4:
                op = p2.decode()
            elif f2 == 5:
                name = next(p for f3, _, p in fields(p2) if f3 == 1)
                attrs[name.decode()] = True
        if op == "Conv":
            assert "auto_pad" in attrs and "pads" not in attrs


def test_sppf_is_pool_cascade_reconcatenated_with_input():
    nodes = nodes_of(graph_of(ex.emit(ex.yolov5l())))
    sppf = [n for n in nodes if n["op"] == "Concat" and len(n["inputs"]) == 4]
    assert sppf, "yolov5l must contain the 4-tap SPPF concat"
    x, p1, p2, p3 = sppf[0]["inputs"]
    pools = {n["outputs"][0]: n for n in nodes if n["op"] == "MaxPool"}
    assert pools[p1]["inputs"] == [x]
    assert pools[p2]["inputs"] == [p1]
    assert pools[p3]["inputs"] == [p2]


def test_weight_initializers_are_shape_only():
    graph = graph_of(ex.emit(ex.yolov5l()))
    for field, _, payload in fields(graph):
        if field != 5:
            continue
        tf = {f2 for f2, _, _ in fields(payload)}
        name = next(p for f2, _, p in fields(payload) if f2 == 8).decode()
        if name.startswith(("w", "b")):
            assert 9 not in tf and 4 not in tf, f"{name} carries weight data"
        else:  # Resize scales carry real floats
            assert name.startswith("sc") and 9 in tf


def test_model_names_match_zoo():
    expected = {
        "mnist": "mnist-8-16-32",
        "resnet50": "resnet50",
        "yolov5l": "yolov5l",
        "unet_tiny": "unet-tiny",
    }
    for key, want in expected.items():
        graph = graph_of(ex.emit(ex.MODELS[key]()))
        name = next(p for f, _, p in fields(graph) if f == 2).decode()
        assert name == want
