"""Synthetic dataset substitute: determinism, shapes, learnability."""

import numpy as np
import pytest

from compile import data


def test_shapes_match_paper_benchmarks():
    ds = data.make_dataset("mnist", n_train=64, n_test=16)
    assert ds.x_train.shape == (64, 28, 28, 1)
    assert ds.num_classes == 10
    ds = data.make_dataset("cifar10", n_train=32, n_test=8)
    assert ds.x_train.shape == (32, 32, 32, 3)
    assert ds.input_shape == (32, 32, 3)


def test_deterministic_across_calls():
    a = data.make_dataset("mnist", n_train=32, n_test=8)
    b = data.make_dataset("mnist", n_train=32, n_test=8)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_train, b.y_train)


def test_seed_changes_data():
    a = data.make_dataset("mnist", n_train=32, n_test=8, seed=0)
    b = data.make_dataset("mnist", n_train=32, n_test=8, seed=1)
    assert not np.array_equal(a.x_train, b.x_train)


def test_datasets_differ_by_name():
    a = data.make_dataset("svhn", n_train=16, n_test=4)
    b = data.make_dataset("cifar10", n_train=16, n_test=4)
    assert not np.array_equal(a.x_train, b.x_train)


def test_pixel_range_and_dtype():
    ds = data.make_dataset("mnist", n_train=64, n_test=16)
    assert ds.x_train.dtype == np.float32
    assert 0.0 <= ds.x_train.min() and ds.x_train.max() <= 1.0


def test_all_classes_present():
    ds = data.make_dataset("mnist", n_train=512, n_test=128)
    assert set(np.unique(ds.y_train)) == set(range(10))


def test_unknown_name_raises():
    with pytest.raises(ValueError):
        data.make_dataset("imagenet")


def test_linearly_separable_enough():
    """A ridge classifier on raw pixels must beat chance by a wide margin —
    guards against regressions that make the set unlearnable."""
    ds = data.make_dataset("mnist", n_train=512, n_test=128)
    x = ds.x_train.reshape(len(ds.x_train), -1)
    xt = ds.x_test.reshape(len(ds.x_test), -1)
    y = np.eye(10)[ds.y_train]
    w = np.linalg.solve(x.T @ x + 10.0 * np.eye(x.shape[1]), x.T @ y)
    acc = (np.argmax(xt @ w, 1) == ds.y_test).mean()
    assert acc > 0.5, acc
