"""L2 model tests: morph-path semantics, shapes, counts, pallas/ref parity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import MorphPath, ModelSpec

SPEC = model.SPECS["mnist"]


def test_specs_registered():
    assert set(model.SPECS) == {"mnist", "svhn", "cifar10"}
    assert model.SPECS["cifar10"].filters == (8, 16, 32, 64, 64)


def test_paths_enumeration():
    names = [p.name for p in SPEC.paths]
    assert names == ["d1_w100", "d2_w100", "d3_w100", "d3_w50"]
    assert SPEC.full_path == MorphPath(3, 100)


def test_init_params_shapes():
    params = model.init_params(SPEC)
    assert len(params["blocks"]) == 3
    assert params["blocks"][0]["w"].shape == (3, 3, 1, 8)
    assert params["blocks"][2]["w"].shape == (3, 3, 16, 32)
    # head dims: flattened feature map after depth-d pooling chain
    assert params["heads"]["d1_w100"]["w"].shape == (14 * 14 * 8, 10)
    assert params["heads"]["d3_w100"]["w"].shape == (3 * 3 * 32, 10)
    assert params["heads"]["d3_w50"]["w"].shape == (3 * 3 * 16, 10)


@pytest.mark.parametrize("path", SPEC.paths, ids=lambda p: p.name)
def test_forward_shapes(path):
    params = model.init_params(SPEC)
    x = jnp.zeros((2, 28, 28, 1), jnp.float32)
    logits = model.forward(params, x, SPEC, path)
    assert logits.shape == (2, 10)


def test_forward_unknown_head_raises():
    params = model.init_params(SPEC)
    x = jnp.zeros((1, 28, 28, 1), jnp.float32)
    with pytest.raises(KeyError):
        model.forward(params, x, SPEC, MorphPath(2, 50))


def test_width_slicing_is_prefix():
    """Width morphing must use the FIRST cout/2 filters (gating a fixed
    half of the PE array), so the w50 path shares weights with the full
    path's prefix channels."""
    params = model.init_params(SPEC)
    w, b = model.slice_block(params["blocks"][1], 4, 8)
    np.testing.assert_array_equal(w, params["blocks"][1]["w"][:, :, :4, :8])
    np.testing.assert_array_equal(b, params["blocks"][1]["b"][:8])


def test_pallas_matches_ref_forward():
    """Deploy path (Pallas) == training path (ref) — the parity the AOT
    artifacts rely on."""
    rng = np.random.default_rng(3)
    params = model.init_params(SPEC, seed=1)
    x = jnp.asarray(rng.random((2, 28, 28, 1)), jnp.float32)
    for path in SPEC.paths:
        a = model.forward(params, x, SPEC, path, use_pallas=False)
        b = model.forward(params, x, SPEC, path, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_count_params_full_matches_manual():
    # conv: 3*3*1*8+8, 3*3*8*16+16, 3*3*16*32+32 ; head: 3*3*32*10+10
    want = (72 + 8) + (1152 + 16) + (4608 + 32) + (288 * 10 + 10)
    assert model.count_params(SPEC, SPEC.full_path) == want


def test_count_params_shapes():
    # NOTE: params are NOT monotone in depth — shallow paths flatten a
    # larger feature map into their FC head (14x14x8 vs 3x3x32), so d1
    # carries the biggest head. MACs (test below) are the monotone cost.
    p1 = model.count_params(SPEC, MorphPath(1, 100))
    w50 = model.count_params(SPEC, MorphPath(3, 50))
    p3 = model.count_params(SPEC, MorphPath(3, 100))
    # d1: conv 72+8, head 1568*10+10
    assert p1 == 80 + 15_690
    assert w50 < p3


def test_count_macs_dominated_by_conv():
    full = model.count_macs(SPEC, SPEC.full_path)
    d1 = model.count_macs(SPEC, MorphPath(1, 100))
    assert full > d1 > 0
    # conv1: 28*28*3*3*1*8 = 56448
    assert d1 == 28 * 28 * 9 * 8 + 14 * 14 * 8 * 10


def test_feature_shape_chain():
    assert model.feature_shape(SPEC, 0) == (28, 28)
    assert model.feature_shape(SPEC, 1) == (14, 14)
    assert model.feature_shape(SPEC, 3) == (3, 3)
    svhn = model.SPECS["svhn"]
    assert model.feature_shape(svhn, 4) == (2, 2)


def test_width_is_never_zero():
    spec = ModelSpec("tiny", (8, 8, 1), 2, (1, 2))
    params = model.init_params(spec)
    x = jnp.zeros((1, 8, 8, 1), jnp.float32)
    logits = model.forward(params, x, spec, MorphPath(2, 50))
    assert logits.shape == (1, 2)
