"""AOT path: HLO text emission, manifest integrity, param cache round-trip."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

SPEC = model.SPECS["mnist"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(SPEC, seed=7)


def test_to_hlo_text_smoke():
    fn = lambda x: (jnp.sum(x * 2.0),)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lower_path_emits_hlo(params):
    text = aot.lower_path(SPEC, params, model.MorphPath(1, 100), batch=1)
    assert "HloModule" in text
    # conv lowers to convolution or dot after im2col; the pallas interpret
    # path emits dot (im2col x matmul)
    assert "dot(" in text or "convolution" in text


def test_lower_path_batch_shows_in_entry(params):
    t1 = aot.lower_path(SPEC, params, model.MorphPath(1, 100), batch=1)
    t8 = aot.lower_path(SPEC, params, model.MorphPath(1, 100), batch=8)
    assert "f32[1,28,28,1]" in t1
    assert "f32[8,28,28,1]" in t8


def test_param_cache_roundtrip(params):
    flat = aot._flatten_params(params)
    back = aot._unflatten_params(flat)
    assert len(back["blocks"]) == len(params["blocks"])
    for a, b in zip(params["blocks"], back["blocks"]):
        np.testing.assert_array_equal(a["w"], b["w"])
    for name in params["heads"]:
        np.testing.assert_array_equal(
            params["heads"][name]["b"], back["heads"][name]["b"]
        )


def test_train_key_stable_and_sensitive():
    from compile import train

    cfg = train.TrainConfig()
    k1 = aot._train_key("mnist", cfg, 2048)
    k2 = aot._train_key("mnist", cfg, 2048)
    k3 = aot._train_key("mnist", cfg._replace(lr=0.5), 2048)
    assert k1 == k2
    assert k1 != k3


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ARTIFACTS, "manifest.json")


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
class TestManifest:
    def setup_method(self):
        with open(MANIFEST) as f:
            self.manifest = json.load(f)

    def test_structure(self):
        assert self.manifest["version"] == 1
        assert "mnist" in self.manifest["models"]
        m = self.manifest["models"]["mnist"]
        assert m["input_shape"] == [28, 28, 1]
        assert [p["name"] for p in m["paths"]] == [
            "d1_w100", "d2_w100", "d3_w100", "d3_w50",
        ]

    def test_artifact_files_exist(self):
        m = self.manifest["models"]["mnist"]
        for path in m["paths"]:
            for fname in path["artifacts"].values():
                assert os.path.exists(os.path.join(ARTIFACTS, fname)), fname

    def test_accuracy_ordering(self):
        """full >= depth subnets, and every path well above chance — the
        DistillCycle property NeuroMorph's runtime trade-off relies on."""
        m = self.manifest["models"]["mnist"]
        acc = {p["name"]: p["accuracy"] for p in m["paths"]}
        assert acc["d3_w100"] >= max(acc["d1_w100"], acc["d3_w50"]) - 0.01
        for v in acc.values():
            assert v > 0.5

    def test_macs_monotone(self):
        m = self.manifest["models"]["mnist"]
        macs = {p["name"]: p["macs"] for p in m["paths"]}
        assert macs["d1_w100"] < macs["d2_w100"] < macs["d3_w100"]
        assert macs["d3_w50"] < macs["d3_w100"]

    def test_probe_recorded(self):
        m = self.manifest["models"]["mnist"]
        probe = m["probe"]
        n = probe["shape"][0]
        assert len(probe["x"]) == n * 28 * 28 * 1
        for path in m["paths"]:
            assert len(probe["logits"][path["name"]]) == n * 10
