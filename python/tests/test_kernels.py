"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal of the compile path — every kernel
that ends up in an AOT artifact is validated here, including hypothesis
sweeps over shapes, strides, kernel sizes and dtypes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, conv2d as conv_k, fc as fc_k, pool as pool_k, ref

RNG = np.random.default_rng(42)


def rand(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("k", [2, 3, 5])
def test_conv_matches_ref(stride, padding, k):
    x = rand(2, 12, 11, 3)
    w = rand(k, k, 3, 7)
    b = rand(7)
    got = conv_k.conv2d(x, w, b, stride=stride, padding=padding, relu=False)
    want = ref.conv2d(x, w, b, stride=stride, padding=padding, relu=False)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_conv_relu():
    x = rand(1, 8, 8, 2)
    w = rand(3, 3, 2, 4)
    got = conv_k.conv2d(x, w, relu=True)
    want = ref.conv2d(x, w, relu=True)
    assert float(jnp.min(got)) >= 0.0
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_conv_no_bias_defaults_zero():
    x = rand(1, 6, 6, 1)
    w = rand(3, 3, 1, 2)
    np.testing.assert_allclose(
        conv_k.conv2d(x, w), ref.conv2d(x, w), rtol=3e-5, atol=3e-5
    )


def test_conv_rejects_bad_weight_shape():
    with pytest.raises(ValueError):
        conv_k.conv2d(rand(1, 6, 6, 2), rand(3, 3, 3, 4))


def test_conv_rejects_bad_padding():
    with pytest.raises(ValueError):
        conv_k.conv2d(rand(1, 6, 6, 1), rand(3, 3, 1, 1), padding="FULL")


@pytest.mark.parametrize("tile_h", [1, 2, 3, 8, 64])
def test_conv_tile_h_invariance(tile_h):
    """The grid tiling is a schedule, not semantics — results identical."""
    x = rand(1, 13, 9, 2)
    w = rand(3, 3, 2, 3)
    got = conv_k.conv2d(x, w, tile_h=tile_h)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("qbits,tol", [(8, 0.6), (16, 0.01)])
def test_conv_quantized_close(qbits, tol):
    """intN datapath: close to f32, with error shrinking 8 -> 16 bits."""
    x = rand(1, 10, 10, 3)
    w = rand(3, 3, 3, 5) * 0.2
    got = conv_k.conv2d(x, w, qbits=qbits)
    want = ref.conv2d(x, w)
    assert float(jnp.max(jnp.abs(got - want))) < tol


def test_conv_int16_tighter_than_int8():
    x = rand(1, 10, 10, 3)
    w = rand(3, 3, 3, 5) * 0.2
    want = ref.conv2d(x, w)
    e8 = float(jnp.max(jnp.abs(conv_k.conv2d(x, w, qbits=8) - want)))
    e16 = float(jnp.max(jnp.abs(conv_k.conv2d(x, w, qbits=16) - want)))
    assert e16 < e8


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 2),
    h=st.integers(4, 14),
    w=st.integers(4, 14),
    cin=st.integers(1, 4),
    cout=st.integers(1, 6),
    k=st.integers(2, 4),
    stride=st.integers(1, 2),
    padding=st.sampled_from(["SAME", "VALID"]),
)
def test_conv_hypothesis(n, h, w, cin, cout, k, stride, padding):
    if padding == "VALID" and (h < k or w < k):
        return
    x = rand(n, h, w, cin)
    wt = rand(k, k, cin, cout)
    b = rand(cout)
    got = conv_k.conv2d(x, wt, b, stride=stride, padding=padding)
    want = ref.conv2d(x, wt, b, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# fc
# ---------------------------------------------------------------------------


def test_fc_matches_ref():
    x = rand(4, 33)
    w = rand(33, 17)
    b = rand(17)
    np.testing.assert_allclose(
        fc_k.fc(x, w, b), ref.fc(x, w, b), rtol=3e-5, atol=3e-5
    )


@pytest.mark.parametrize("tile_o", [1, 4, 16, 128])
def test_fc_tile_o_invariance(tile_o):
    """tile_o is the FC_PE allocation count — a schedule knob only."""
    x = rand(2, 19)
    w = rand(19, 11)
    got = fc_k.fc(x, w, tile_o=tile_o)
    np.testing.assert_allclose(got, ref.fc(x, w), rtol=3e-5, atol=3e-5)


def test_fc_relu_and_quant():
    x = rand(3, 21)
    w = rand(21, 9) * 0.3
    got = fc_k.fc(x, w, relu=True)
    want = ref.fc(x, w, relu=True)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
    gq = fc_k.fc(x, w, qbits=8)
    assert float(jnp.max(jnp.abs(gq - ref.fc(x, w)))) < 0.6


def test_fc_rejects_bad_shapes():
    with pytest.raises(ValueError):
        fc_k.fc(rand(2, 5), rand(6, 3))


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 4),
    f=st.integers(1, 40),
    o=st.integers(1, 20),
    tile_o=st.sampled_from([1, 3, 8, 128]),
)
def test_fc_hypothesis(n, f, o, tile_o):
    x = rand(n, f)
    w = rand(f, o)
    b = rand(o)
    got = fc_k.fc(x, w, b, tile_o=tile_o)
    np.testing.assert_allclose(got, ref.fc(x, w, b), rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# pooling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,stride", [(2, 2), (2, 1), (3, 3), (3, 2)])
def test_maxpool_matches_ref(k, stride):
    x = rand(2, 11, 13, 4)
    np.testing.assert_allclose(
        pool_k.maxpool2d(x, k, stride), ref.maxpool2d(x, k, stride), rtol=1e-6
    )


@pytest.mark.parametrize("k,stride", [(2, 2), (3, 1)])
def test_avgpool_matches_ref(k, stride):
    x = rand(2, 9, 10, 3)
    np.testing.assert_allclose(
        pool_k.avgpool2d(x, k, stride), ref.avgpool2d(x, k, stride),
        rtol=1e-5, atol=1e-6,
    )


def test_pool_rejects_small_frame():
    with pytest.raises(ValueError):
        pool_k.maxpool2d(rand(1, 1, 1, 1), 2)


def test_global_avg_pool():
    x = rand(3, 7, 5, 6)
    np.testing.assert_allclose(
        pool_k.global_avg_pool(x), ref.global_avg_pool(x), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    c=st.integers(1, 5),
    k=st.integers(2, 3),
    stride=st.integers(1, 3),
)
def test_pool_hypothesis(h, w, c, k, stride):
    if h < k or w < k:
        return
    x = rand(1, h, w, c)
    np.testing.assert_allclose(
        pool_k.maxpool2d(x, k, stride), ref.maxpool2d(x, k, stride), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# shape helpers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "size,k,stride,want",
    [(28, 3, 1, 28), (28, 3, 2, 14), (7, 2, 2, 4), (5, 5, 1, 5)],
)
def test_out_size_same(size, k, stride, want):
    assert common.out_size(size, k, stride, "SAME") == want


@pytest.mark.parametrize(
    "size,k,stride,want",
    [(28, 3, 1, 26), (28, 3, 2, 13), (7, 2, 2, 3), (5, 5, 1, 1)],
)
def test_out_size_valid(size, k, stride, want):
    assert common.out_size(size, k, stride, "VALID") == want


def test_same_pads_cover():
    for size in range(3, 20):
        for k in (2, 3, 5):
            for s in (1, 2):
                lo, hi = common.same_pads(size, k, s)
                out = common.out_size(size, k, s, "SAME")
                assert (size + lo + hi - k) // s + 1 == out
