"""DistillCycle training dynamics (Algorithm 2).

Small budgets keep this suite in tens of seconds; the assertions are about
*dynamics* (losses fall, KD helps, ordering holds), not absolute accuracy.
"""

import functools

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model, train


@functools.lru_cache(maxsize=1)
def _trained():
    ds = data.make_dataset("mnist", n_train=1024, n_test=256)
    spec = model.SPECS["mnist"]
    cfg = train.TrainConfig(epochs_per_stage=3)
    return spec, ds, cfg, train.distillcycle_train(spec, ds, cfg)


def test_losses_decrease_within_teacher_phase():
    _, _, _, res = _trained()
    teacher_stage1 = [h[4] for h in res.loss_history if h[:3] == (1, "teacher", "d1_w100")]
    assert teacher_stage1[-1] < teacher_stage1[0]


def test_all_paths_beat_chance():
    spec, _, _, res = _trained()
    for path in spec.paths:
        assert res.accuracies[path.name] > 0.25, res.accuracies


def test_every_path_has_history():
    _, _, _, res = _trained()
    trained_names = {h[2] for h in res.loss_history}
    assert {"d1_w100", "d2_w100", "d3_w100", "d3_w50"} <= trained_names


def test_polish_phase_runs_last():
    _, _, _, res = _trained()
    assert res.loss_history[-1][1] == "polish"
    assert res.loss_history[-1][2] == "d3_w100"


def test_kd_loss_zero_when_matching():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((4, 10)), jnp.float32)
    assert float(train.kd_loss(logits, logits, tau=3.0)) < 1e-5


def test_kd_loss_positive_when_differing():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((4, 10)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((4, 10)), jnp.float32)
    assert float(train.kd_loss(a, b, tau=3.0)) > 0.0


def test_cross_entropy_perfect_prediction():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    y = jnp.asarray([0, 1])
    assert float(train.cross_entropy(logits, y)) < 1e-3


def test_lr_tree_decays_early_blocks():
    spec = model.SPECS["mnist"]
    params = model.init_params(spec)
    tree = train._lr_tree(params, spec, stage=3, base_lr=0.1, gamma=0.5)
    lrs = [tree["blocks"][j]["w"] for j in range(3)]
    assert lrs == [0.025, 0.05, 0.1]  # γ^2, γ^1, γ^0
    assert tree["heads"]["d1_w100"]["w"] == 0.1


def test_lr_tree_head_override():
    spec = model.SPECS["mnist"]
    params = model.init_params(spec)
    tree = train._lr_tree(params, spec, 2, 0.01, 0.5, head_lr=0.3)
    assert tree["heads"]["d3_w100"]["b"] == 0.3


@pytest.mark.slow
def test_distillcycle_vs_specialist():
    """DistillCycle's d1 path shares its trunk with three other paths, so
    a d1-only specialist (same step budget, labels only) is the upper
    bound. The claim: DistillCycle stays within ~12 pts of the specialist
    while ALSO delivering the deeper paths the specialist doesn't have."""
    spec, ds, cfg, res = _trained()
    specialist = train.label_only_train(spec, ds, model.MorphPath(1, 100), cfg)
    assert res.accuracies["d1_w100"] >= specialist - 0.12, (
        res.accuracies["d1_w100"],
        specialist,
    )
    # the multi-path dividend: total deployable accuracy across paths
    total = sum(res.accuracies.values())
    assert total > specialist + 1.0, (total, specialist)
