"""Deterministic synthetic classification datasets (dataset substitute).

The paper trains on MNIST / SVHN / CIFAR-10. This environment is offline,
so we substitute procedurally generated datasets with the *same tensor
shapes and class counts* (DESIGN.md §2). Each class is a fixed random
mixture of 2-D sinusoidal gratings and Gaussian blobs; samples perturb the
class template with per-sample amplitude jitter and additive noise. The
resulting sets are separable but noisy: a shallow subnet reaches lower
accuracy than the full net, which is exactly the accuracy-vs-depth/width
gradient DistillCycle and NeuroMorph exercise.

Everything is seeded — two processes generate byte-identical datasets.
"""

from __future__ import annotations

import hashlib
from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    """Train/test split with NHWC images in [0, 1] and integer labels."""

    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int

    @property
    def input_shape(self) -> tuple[int, int, int]:
        return self.x_train.shape[1:]


#: shape/class layout of the paper's benchmark sets (Table II)
SPECS = {
    "mnist": dict(h=28, w=28, c=1, classes=10),
    "svhn": dict(h=32, w=32, c=3, classes=10),
    "cifar10": dict(h=32, w=32, c=3, classes=10),
}


def _class_templates(
    rng: np.random.Generator, h: int, w: int, c: int, classes: int
) -> np.ndarray:
    """One [h,w,c] template per class: gratings + blobs, unit-normalized."""
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    templates = np.zeros((classes, h, w, c), np.float32)
    for cls in range(classes):
        img = np.zeros((h, w, c), np.float32)
        # sinusoidal gratings — orientation/frequency keyed to the class
        for _ in range(3):
            fx, fy = rng.uniform(0.5, 3.0, size=2)
            phase = rng.uniform(0, 2 * np.pi)
            grating = np.sin(2 * np.pi * (fx * xx / w + fy * yy / h) + phase)
            chan = rng.integers(0, c)
            img[:, :, chan] += grating.astype(np.float32)
        # gaussian blobs — spatial landmarks
        for _ in range(2):
            cx, cy = rng.uniform(0.2, 0.8, size=2) * (w, h)
            sigma = rng.uniform(0.08, 0.2) * min(h, w)
            blob = np.exp(-(((xx - cy) ** 2 + (yy - cx) ** 2) / (2 * sigma**2)))
            img += blob[:, :, None].astype(np.float32)
        img -= img.mean()
        img /= max(img.std(), 1e-6)
        templates[cls] = img
    return templates


def _stable_seed(name: str, seed: int) -> int:
    """Process-independent seed (``hash(str)`` is salted per interpreter)."""
    digest = hashlib.sha256(name.encode()).digest()
    return int.from_bytes(digest[:4], "little") + seed


def make_dataset(
    name: str,
    n_train: int = 2048,
    n_test: int = 512,
    noise: float = 1.0,
    max_shift: int = 2,
    seed: int = 0,
) -> Dataset:
    """Build the named synthetic set (``mnist`` / ``svhn`` / ``cifar10``).

    ``noise`` and ``max_shift`` (random per-sample spatial translation)
    control difficulty: shifts make shallow subnets strictly weaker than
    deep ones — the accuracy-vs-depth gradient NeuroMorph trades on.
    """
    if name not in SPECS:
        raise ValueError(f"unknown dataset {name!r}; have {sorted(SPECS)}")
    spec = SPECS[name]
    h, w, c, classes = spec["h"], spec["w"], spec["c"], spec["classes"]
    rng = np.random.default_rng(_stable_seed(name, seed))
    templates = _class_templates(rng, h, w, c, classes)

    def sample(n: int) -> tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, classes, size=n)
        amp = rng.uniform(0.7, 1.3, size=(n, 1, 1, 1)).astype(np.float32)
        x = templates[y] * amp
        if max_shift > 0:
            shifts = rng.integers(-max_shift, max_shift + 1, size=(n, 2))
            x = np.stack(
                [np.roll(img, tuple(s), axis=(0, 1)) for img, s in zip(x, shifts)]
            )
        x += rng.normal(0.0, noise, size=x.shape).astype(np.float32)
        # map to [0,1] like pixel data
        x = (x - x.min()) / max(x.max() - x.min(), 1e-6)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return Dataset(name, x_tr, y_tr, x_te, y_te, classes)
