"""Offline ONNX corpus writer: the zoo models as real exported graphs.

Writes ONNX ``ModelProto`` **wire format directly** — hand-rolled varint
/ length-delimited emission, zero dependencies (no ``onnx``, no
``torch``) — so the corpus builds in the offline CI container. The
emission rules mirror ``rust/src/onnx/export.rs`` exactly: one final
tensor per layer named ``t{id}``, fused relu split into ``Conv``/``Gemm``
+ ``Relu`` node pairs, conv padding spelled as ``auto_pad`` (never a
``pads`` array), SPPF as the stride-1 same-padded MaxPool cascade, and
**shape-only** weight initializers (dims + dtype, no payload — the
analytical flow never reads weight values, and yolov5l's real weights
would be ~180 MB).

CI round-trips every file through ``forgemorph graph dump --onnx`` and
diffs the JSON against ``graph dump --model`` — the imported StagePlan
must be bit-identical to the hand-built zoo twin (docs/ONNX.md).

Usage::

    python -m compile.export_onnx --out corpus/

writes ``corpus/{mnist,svhn,...}.onnx``, one per zoo model.
"""

from __future__ import annotations

import argparse
import os

# ---------------------------------------------------------------------------
# protobuf wire emission
# ---------------------------------------------------------------------------

DT_FLOAT = 1
AT_FLOAT, AT_INT, AT_STRING, AT_INTS = 1, 2, 3, 7


def _uv(x: int) -> bytes:
    out = bytearray()
    while True:
        b = x & 0x7F
        x >>= 7
        if x == 0:
            out.append(b)
            return bytes(out)
        out.append(b | 0x80)


def _tag(field: int, wire: int) -> bytes:
    return _uv((field << 3) | wire)


def _vint(field: int, v: int) -> bytes:
    return _tag(field, 0) + _uv(v)


def _ld(field: int, payload: bytes) -> bytes:
    return _tag(field, 2) + _uv(len(payload)) + payload


def _s(field: int, s: str) -> bytes:
    return _ld(field, s.encode())


def _f32le(vals: list[float]) -> bytes:
    import struct

    return b"".join(struct.pack("<f", v) for v in vals)


def attr_int(name: str, v: int) -> bytes:
    return _ld(5, _s(1, name) + _vint(3, v) + _vint(20, AT_INT))


def attr_ints(name: str, vals: list[int]) -> bytes:
    body = _s(1, name) + b"".join(_vint(8, v) for v in vals)
    return _ld(5, body + _vint(20, AT_INTS))


def attr_str(name: str, s: str) -> bytes:
    return _ld(5, _s(1, name) + _s(4, s) + _vint(20, AT_STRING))


def node(name: str, op: str, inputs: list[str], outputs: list[str], attrs: list[bytes]) -> bytes:
    body = b"".join(_s(1, i) for i in inputs)
    body += b"".join(_s(2, o) for o in outputs)
    body += _s(3, name) + _s(4, op) + b"".join(attrs)
    return _ld(1, body)


def tensor_shape_only(name: str, dims: list[int]) -> bytes:
    body = b"".join(_vint(1, d) for d in dims)
    body += _vint(2, DT_FLOAT) + _s(8, name)
    return _ld(5, body)


def tensor_f32(name: str, dims: list[int], vals: list[float]) -> bytes:
    body = b"".join(_vint(1, d) for d in dims)
    body += _vint(2, DT_FLOAT) + _s(8, name) + _ld(9, _f32le(vals))
    return _ld(5, body)


def value_info(field: int, name: str, dims: list[int]) -> bytes:
    shape = b"".join(_ld(1, _vint(1, d)) for d in dims)
    tensor_type = _vint(1, DT_FLOAT) + _ld(2, shape)
    ty = _ld(1, tensor_type)
    return _ld(field, _s(1, name) + _ld(2, ty))


# ---------------------------------------------------------------------------
# NetworkBuilder mirror (ids, names, tails — rust/src/graph/builder.rs)
# ---------------------------------------------------------------------------


def _out_hw(h: int, w: int, k: int, stride: int, padding: str) -> tuple[int, int]:
    if padding == "same":
        return -(-h // stride), -(-w // stride)
    return (h - k) // stride + 1, (w - k) // stride + 1


class Net:
    """Mirror of the Rust ``NetworkBuilder``: identical layer ids, names
    (``{op}{id}``), chain-tail semantics and connection push order, plus
    the output-shape tracking the exporter needs for weight dims."""

    def __init__(self, name: str, h: int, w: int, c: int):
        self.name = name
        self.layers: list[dict] = [{"name": "input", "op": "input"}]
        self.shapes: list[tuple[int, int, int]] = [(h, w, c)]
        self.connections: list[tuple[int, int]] = []
        self.tail = 0

    def _push(self, prefix: str, layer: dict, out_shape: tuple[int, int, int]) -> "Net":
        lid = len(self.layers)
        layer["name"] = f"{prefix}{lid}"
        self.layers.append(layer)
        self.shapes.append(out_shape)
        self.connections.append((self.tail, lid))
        self.tail = lid
        return self

    def _in(self) -> tuple[int, int, int]:
        return self.shapes[self.tail]

    def conv(self, filters, k, stride=1, padding="same", relu=True):
        h, w, c = self._in()
        oh, ow = _out_hw(h, w, k, stride, padding)
        layer = dict(op="conv", filters=filters, k=k, stride=stride,
                     padding=padding, relu=relu, cin=c)
        return self._push("conv", layer, (oh, ow, filters))

    def dwconv(self, k, stride=1, padding="same", relu=True):
        h, w, c = self._in()
        oh, ow = _out_hw(h, w, k, stride, padding)
        layer = dict(op="dwconv", k=k, stride=stride, padding=padding,
                     relu=relu, cin=c)
        return self._push("dwconv", layer, (oh, ow, c))

    def maxpool(self, k, stride):
        h, w, c = self._in()
        return self._push("maxpool", dict(op="maxpool", k=k, stride=stride),
                          ((h - k) // stride + 1, (w - k) // stride + 1, c))

    def avgpool(self, k, stride):
        h, w, c = self._in()
        return self._push("avgpool", dict(op="avgpool", k=k, stride=stride),
                          ((h - k) // stride + 1, (w - k) // stride + 1, c))

    def global_avg_pool(self):
        _, _, c = self._in()
        return self._push("gap", dict(op="gap"), (1, 1, c))

    def fc(self, out, relu=False):
        h, w, c = self._in()
        return self._push("fc", dict(op="fc", out=out, relu=relu,
                                     in_features=h * w * c), (1, 1, out))

    def softmax(self):
        return self._push("softmax", dict(op="softmax"), self._in())

    def relu(self):
        return self._push("relu", dict(op="relu"), self._in())

    def upsample(self, factor):
        h, w, c = self._in()
        return self._push("up", dict(op="upsample", factor=factor),
                          (h * factor, w * factor, c))

    def sppf(self, k):
        h, w, c = self._in()
        return self._push("sppf", dict(op="sppf", k=k), (h, w, 4 * c))

    def mark(self) -> int:
        return self.tail

    fork = mark

    def branch_from(self, lid: int) -> "Net":
        self.tail = lid
        return self

    def residual_add(self, fork: int) -> "Net":
        lid = len(self.layers)
        self._push("resadd", dict(op="resadd", skip=fork), self._in())
        self.connections.append((fork, lid))
        return self

    def concat(self, sources: list[int]) -> "Net":
        lid = len(self.layers)
        h, w, _ = self.shapes[sources[0]]
        c = sum(self.shapes[s][2] for s in sources)
        self.layers.append({"name": f"concat{lid}", "op": "concat",
                            "from": list(sources)})
        self.shapes.append((h, w, c))
        for s in sources:
            self.connections.append((s, lid))
        self.tail = lid
        return self


# ---------------------------------------------------------------------------
# ONNX emission (mirrors rust/src/onnx/export.rs emit_layer)
# ---------------------------------------------------------------------------


def _auto_pad(padding: str) -> str:
    return "SAME_UPPER" if padding == "same" else "VALID"


def _preds(net: Net) -> list[list[int]]:
    preds: list[list[int]] = [[] for _ in net.layers]
    for s, d in net.connections:
        if s < d:
            preds[d].append(s)
    return preds


def emit(net: Net) -> bytes:
    preds = _preds(net)
    outdeg = [0] * len(net.layers)
    for s, d in net.connections:
        if s < d:
            outdeg[s] += 1

    g = bytearray()
    for lid in range(1, len(net.layers)):
        layer = net.layers[lid]
        pin = preds[lid][0] if preds[lid] else lid - 1
        x, out = f"t{pin}", f"t{lid}"
        op = layer["op"]
        name = layer["name"]
        if op in ("conv", "dwconv"):
            k, stride, cin = layer["k"], layer["stride"], layer["cin"]
            wn, bn = f"w{lid}", f"b{lid}"
            if op == "conv":
                wdims, group = [layer["filters"], cin, k, k], None
            else:
                wdims, group = [cin, 1, k, k], cin
            g += tensor_shape_only(wn, wdims)
            g += tensor_shape_only(bn, wdims[:1])
            conv_out = f"{out}c" if layer["relu"] else out
            attrs = [attr_str("auto_pad", _auto_pad(layer["padding"]))]
            if group is not None:
                attrs.append(attr_int("group", group))
            attrs += [attr_ints("kernel_shape", [k, k]),
                      attr_ints("strides", [stride, stride])]
            g += node(name, "Conv", [x, wn, bn], [conv_out], attrs)
            if layer["relu"]:
                g += node(f"{name}_relu", "Relu", [conv_out], [out], [])
        elif op == "maxpool":
            g += node(name, "MaxPool", [x], [out],
                      [attr_ints("kernel_shape", [layer["k"]] * 2),
                       attr_ints("strides", [layer["stride"]] * 2)])
        elif op == "avgpool":
            g += node(name, "AveragePool", [x], [out],
                      [attr_ints("kernel_shape", [layer["k"]] * 2),
                       attr_ints("strides", [layer["stride"]] * 2)])
        elif op == "gap":
            g += node(name, "GlobalAveragePool", [x], [out], [])
        elif op == "fc":
            flat = f"{out}f"
            g += node(f"{name}_flatten", "Flatten", [x], [flat],
                      [attr_int("axis", 1)])
            wn, bn = f"w{lid}", f"b{lid}"
            g += tensor_shape_only(wn, [layer["out"], layer["in_features"]])
            g += tensor_shape_only(bn, [layer["out"]])
            gemm_out = f"{out}g" if layer["relu"] else out
            g += node(name, "Gemm", [flat, wn, bn], [gemm_out],
                      [attr_int("transB", 1)])
            if layer["relu"]:
                g += node(f"{name}_relu", "Relu", [gemm_out], [out], [])
        elif op == "resadd":
            g += node(name, "Add", [x, f"t{layer['skip']}"], [out], [])
        elif op == "concat":
            g += node(name, "Concat", [f"t{p}" for p in preds[lid]], [out],
                      [attr_int("axis", 1)])
        elif op == "upsample":
            sc = f"sc{lid}"
            f = float(layer["factor"])
            g += tensor_f32(sc, [4], [1.0, 1.0, f, f])
            g += node(name, "Resize", [x, "", sc], [out],
                      [attr_str("mode", "nearest")])
        elif op == "sppf":
            k = layer["k"]
            pad = (k - 1) // 2
            pool_attrs = [attr_ints("kernel_shape", [k, k]),
                          attr_ints("pads", [pad] * 4),
                          attr_ints("strides", [1, 1])]
            taps = [f"{out}p{i}" for i in (1, 2, 3)]
            src = x
            for i, t in enumerate(taps):
                g += node(f"{name}_pool{i + 1}", "MaxPool", [src], [t],
                          pool_attrs)
                src = t
            g += node(name, "Concat", [x, *taps], [out], [attr_int("axis", 1)])
        elif op == "relu":
            g += node(name, "Relu", [x], [out], [])
        elif op == "softmax":
            g += node(name, "Softmax", [x], [out], [attr_int("axis", 1)])
        else:  # pragma: no cover - builder only produces the ops above
            raise ValueError(f"unhandled op {op!r}")

    g += _s(2, net.name)
    h, w, c = net.shapes[0]
    g += value_info(11, "t0", [1, c, h, w])
    for lid, layer in enumerate(net.layers):
        if outdeg[lid] == 0:
            oh, ow, oc = net.shapes[lid]
            g += value_info(12, f"t{lid}", [1, oc, oh, ow])

    m = _vint(1, 8)  # ir_version
    m += _s(2, "forgemorph")
    m += _s(3, "0.2.0")  # mirrors rust crate version
    m += _ld(7, bytes(g))
    m += _ld(8, _vint(2, 13))  # opset_import { version: 13 }
    return bytes(m)


# ---------------------------------------------------------------------------
# zoo mirrors (rust/src/graph/zoo.rs, layer for layer)
# ---------------------------------------------------------------------------


def mnist() -> Net:
    b = Net("mnist-8-16-32", 28, 28, 1)
    for f in (8, 16, 32):
        b = b.conv(f, 3, 1).maxpool(2, 2)
    return b.fc(10).softmax()


def svhn() -> Net:
    b = Net("svhn-8-16-32-64", 32, 32, 3)
    for f in (8, 16, 32, 64):
        b = b.conv(f, 3, 1).maxpool(2, 2)
    return b.fc(10).softmax()


def cifar10() -> Net:
    b = Net("cifar10-8-16-32-64-64", 32, 32, 3)
    for i, f in enumerate((8, 16, 32, 64, 64)):
        b = b.conv(f, 3, 1)
        if i < 4:
            b = b.maxpool(2, 2)
    return b.fc(10).softmax()


def resnet50() -> Net:
    b = Net("resnet50", 224, 224, 3).conv(64, 7, 2).maxpool(2, 2)
    for planes, blocks, stride in ((64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)):
        for blk in range(blocks):
            s = stride if blk == 0 else 1
            fork = b.fork()
            b = (b.conv(planes, 1, s)
                  .conv(planes, 3, 1)
                  .conv(planes * 4, 1, 1, relu=False))
            if blk != 0:
                b = b.residual_add(fork)
    return b.global_avg_pool().fc(1000).softmax()


def mobilenet_v2() -> Net:
    b = Net("mobilenetv2", 224, 224, 3).conv(32, 3, 2)
    settings = ((1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
                (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1))
    cin = 32
    for t, c, n, s in settings:
        for i in range(n):
            stride = s if i == 0 else 1
            if t != 1:
                b = b.conv(cin * t, 1, 1)
            b = b.dwconv(3, stride).conv(c, 1, 1, relu=False)
            cin = c
    return b.conv(1280, 1, 1).global_avg_pool().fc(1000).softmax()


def squeezenet() -> Net:
    b = Net("squeezenet", 224, 224, 3).conv(64, 3, 2).maxpool(2, 2)
    fires = ((16, 128), (16, 128), (32, 256), (32, 256),
             (48, 384), (48, 384), (64, 512), (64, 512))
    for i, (s, e) in enumerate(fires):
        b = b.conv(s, 1, 1).conv(e, 2, 1)
        if i in (2, 4):
            b = b.maxpool(2, 2)
    return b.conv(1000, 1, 1).global_avg_pool().softmax()


def _c3(b: Net, c2: int, n: int, shortcut: bool) -> Net:
    c_ = c2 // 2
    inp = b.mark()
    b = b.conv(c_, 1, 1)  # cv1
    for _ in range(n):
        f = b.mark()
        b = b.conv(c_, 1, 1).conv(c_, 3, 1)
        if shortcut:
            b = b.residual_add(f)
    main = b.mark()
    b = b.branch_from(inp).conv(c_, 1, 1)  # cv2
    side = b.mark()
    return b.concat([main, side]).conv(c2, 1, 1)  # cv3


def yolov5l() -> Net:
    b = Net("yolov5l", 640, 640, 3).conv(64, 6, 2).conv(128, 3, 2)
    b = _c3(b, 128, 3, True)
    b = b.conv(256, 3, 2)
    b = _c3(b, 256, 6, True)
    p3 = b.mark()
    b = b.conv(512, 3, 2)
    b = _c3(b, 512, 9, True)
    p4 = b.mark()
    b = b.conv(1024, 3, 2)
    b = _c3(b, 1024, 3, True)
    b = b.conv(512, 1, 1).sppf(5).conv(1024, 1, 1)
    b = b.conv(512, 1, 1)
    n10 = b.mark()
    b = b.upsample(2)
    up = b.mark()
    b = _c3(b.concat([up, p4]), 512, 3, False)
    b = b.conv(256, 1, 1)
    n14 = b.mark()
    b = b.upsample(2)
    up2 = b.mark()
    b = _c3(b.concat([up2, p3]), 256, 3, False)
    d_p3 = b.mark()
    b = b.conv(256, 3, 2)
    dn = b.mark()
    b = _c3(b.concat([dn, n14]), 512, 3, False)
    d_p4 = b.mark()
    b = b.conv(512, 3, 2)
    dn2 = b.mark()
    b = _c3(b.concat([dn2, n10]), 1024, 3, False)
    d_p5 = b.mark()
    for head in (d_p3, d_p4, d_p5):
        b = b.branch_from(head).conv(255, 1, 1, relu=False)
    return b


def unet_tiny() -> Net:
    b = Net("unet-tiny", 96, 96, 3).conv(16, 3, 1).conv(16, 3, 1)
    e1 = b.mark()
    b = b.maxpool(2, 2).conv(32, 3, 1).conv(32, 3, 1)
    e2 = b.mark()
    b = b.maxpool(2, 2).conv(64, 3, 1).conv(64, 3, 1).upsample(2)
    up2 = b.mark()
    b = b.concat([up2, e2]).conv(32, 3, 1).conv(32, 3, 1).upsample(2)
    up1 = b.mark()
    b = b.concat([up1, e1]).conv(16, 3, 1).conv(16, 3, 1)
    return b.conv(4, 1, 1, relu=False)


#: zoo lookup key -> builder (keys match ``zoo::NAMES`` / ``--model``)
MODELS = {
    "mnist": mnist,
    "svhn": svhn,
    "cifar10": cifar10,
    "resnet50": resnet50,
    "mobilenetv2": mobilenet_v2,
    "squeezenet": squeezenet,
    "yolov5l": yolov5l,
    "unet_tiny": unet_tiny,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="corpus", help="output directory")
    ap.add_argument("--model", choices=sorted(MODELS), action="append",
                    help="export only this model (repeatable; default: all)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = args.model or list(MODELS)
    for key in names:
        data = emit(MODELS[key]())
        path = os.path.join(args.out, f"{key}.onnx")
        with open(path, "wb") as fh:
            fh.write(data)
        print(f"wrote {path} ({len(data)} bytes)")


if __name__ == "__main__":
    main()
