"""DistillCycle training (Sec. IV-B, Algorithm 2).

Jointly optimizes the full network and every morphable subnetwork so that
each (depth, width) path is an accurate standalone execution path:

1. **Grow progressively** — stage ``i`` appends Layer-Block ``B_i``
   (Eq. 19) and trains the depth-``i`` network as the current *teacher*
   with plain cross-entropy (Eq. 16).
2. **Train in cycles** — within each stage, alternate teacher epochs with
   *student* phases over the morph paths revealed so far.
3. **Knowledge distillation** — students minimize
   ``λ·CE + (1−λ)·τ²·KL(σ(t/τ) ‖ σ(s/τ))`` (Eqs. 17–18).
4. **LR decay for stability** — blocks ``j < i`` get exponentially decayed
   learning rates ``α·γ^(i−j)`` (Eq. 20) against catastrophic forgetting.

Manual SGD with momentum (no optax in this environment). Training uses
the pure-jnp reference ops — Python is build-time only; the trained
parameters are frozen into per-path Pallas HLO artifacts by ``aot.py``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .model import ModelSpec, MorphPath


class TrainConfig(NamedTuple):
    """DistillCycle hyperparameters (Algorithm 2's ``params`` input)."""

    lr: float = 0.02  # α0
    momentum: float = 0.9
    lam: float = 0.5  # λ — CE vs KD mix (Eq. 18)
    tau: float = 3.0  # τ — distillation temperature (Eq. 17)
    gamma: float = 0.5  # γ — per-block LR decay (Eq. 20)
    epochs_per_stage: int = 3
    batch: int = 64
    lr_stage_decay: float = 0.6  # α shrink between growth stages (the
    # α ← α/10 of Alg. 2, softened for short synthetic runs); heads are
    # exempt — fresh capacity always trains at the base rate
    seed: int = 0


def cross_entropy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over the batch (Eq. 16)."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def kd_loss(student_logits: jnp.ndarray, teacher_logits: jnp.ndarray, tau: float) -> jnp.ndarray:
    """τ²-scaled KL between softened teacher/student outputs (Eq. 17)."""
    t = jax.nn.softmax(teacher_logits / tau)
    s = jax.nn.log_softmax(student_logits / tau)
    kl = jnp.sum(t * (jnp.log(jnp.clip(t, 1e-9)) - s), axis=1)
    return tau * tau * jnp.mean(kl)


def _tree_sgd(params, grads, vel, lr_tree, momentum):
    """One SGD+momentum step with a per-leaf learning-rate tree."""
    new_vel = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
    new_params = jax.tree.map(lambda p, v, lr: p - lr * v, params, new_vel, lr_tree)
    return new_params, new_vel


def _lr_tree(
    params: dict,
    spec: ModelSpec,
    stage: int,
    base_lr: float,
    gamma: float,
    head_lr: float | None = None,
):
    """Eq. 20: block j < stage trains at base_lr * gamma^(stage-1-j).

    Heads are fresh capacity (never "earlier layers"), so they train at
    ``head_lr`` (default: the undecayed base rate)."""
    head_lr = base_lr if head_lr is None else head_lr

    def block_lr(j: int) -> float:
        return base_lr * (gamma ** max(0, stage - 1 - j))

    tree = {
        "blocks": [
            jax.tree.map(lambda _: block_lr(j), blk)
            for j, blk in enumerate(params["blocks"])
        ],
        "heads": {
            name: jax.tree.map(lambda _: head_lr, head)
            for name, head in params["heads"].items()
        },
    }
    return tree


@functools.partial(jax.jit, static_argnames=("spec", "path", "tau", "lam", "momentum", "distill"))
def _train_step(
    params,
    vel,
    lr_tree,
    x,
    y,
    teacher_logits,
    spec: ModelSpec,
    path: MorphPath,
    tau: float,
    lam: float,
    momentum: float,
    distill: bool,
):
    """One SGD step on one morph path; optionally distilling (Eq. 18)."""

    def loss_fn(p):
        logits = model_mod.forward(p, x, spec, path)
        ce = cross_entropy(logits, y)
        if distill:
            return lam * ce + (1.0 - lam) * kd_loss(logits, teacher_logits, tau)
        return ce

    loss, grads = jax.value_and_grad(loss_fn)(params)
    # global-norm clipping: keeps the alternating teacher/student updates
    # stable across growth stages (momentum + fresh heads can spike early)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-12
    )
    clip = jnp.minimum(1.0, 5.0 / gnorm)
    grads = jax.tree.map(lambda g: g * clip, grads)
    params, vel = _tree_sgd(params, grads, vel, lr_tree, momentum)
    return params, vel, loss


@functools.partial(jax.jit, static_argnames=("spec", "path"))
def _infer(params, x, spec: ModelSpec, path: MorphPath):
    return model_mod.forward(params, x, spec, path)


class TrainResult(NamedTuple):
    params: dict
    accuracies: dict  # path name -> float
    loss_history: list  # (stage, phase, path, epoch, mean loss)


def _epoch_batches(rng: np.random.Generator, n: int, batch: int):
    order = rng.permutation(n)
    for i in range(0, n - batch + 1, batch):
        yield order[i : i + batch]


def distillcycle_train(
    spec: ModelSpec,
    dataset: data_mod.Dataset,
    cfg: TrainConfig = TrainConfig(),
    progress: bool = False,
) -> TrainResult:
    """Algorithm 2: progressive growth with teacher/student cycles."""
    rng = np.random.default_rng(cfg.seed)
    params = model_mod.init_params(spec, cfg.seed)
    vel = jax.tree.map(jnp.zeros_like, params)
    x_tr = jnp.asarray(dataset.x_train)
    y_tr = jnp.asarray(dataset.y_train)
    history: list = []

    n_stages = len(spec.filters)
    alpha = cfg.lr
    for stage in range(1, n_stages + 1):
        teacher_path = MorphPath(stage, 100)
        # students: the previous depth (early-exit branch) and, at the final
        # stage, the half-width variant — the morphing_schedule of Alg. 2.
        students = []
        if stage > 1:
            students.append(MorphPath(stage - 1, 100))
        if stage == n_stages:
            students.append(MorphPath(stage, 50))

        lr_teacher = _lr_tree(params, spec, stage, alpha, cfg.gamma, head_lr=cfg.lr)
        for epoch in range(cfg.epochs_per_stage):
            # Phase 1 — teacher: grow and train N_full^(i) with CE.
            # Velocity is reset at every phase switch: the teacher and the
            # students optimize different losses over shared blocks, and
            # carrying momentum across the switch destabilizes the cycle.
            vel = jax.tree.map(jnp.zeros_like, params)
            losses = []
            for idx in _epoch_batches(rng, x_tr.shape[0], cfg.batch):
                bx, by = x_tr[idx], y_tr[idx]
                params, vel, loss = _train_step(
                    params, vel, lr_teacher, bx, by,
                    jnp.zeros((bx.shape[0], spec.num_classes), jnp.float32),
                    spec, teacher_path, cfg.tau, cfg.lam, cfg.momentum, False,
                )
                losses.append(float(loss))
            history.append((stage, "teacher", teacher_path.name, epoch, float(np.mean(losses))))
            if progress:
                print(f"[stage {stage}] teacher {teacher_path.name} "
                      f"epoch {epoch}: loss {np.mean(losses):.4f}")

            # Phase 2 — students: CE + KD against the fresh teacher
            for spath in students:
                lr_student = _lr_tree(
                    params, spec, stage, alpha, cfg.gamma, head_lr=cfg.lr
                )
                vel = jax.tree.map(jnp.zeros_like, params)
                losses = []
                for idx in _epoch_batches(rng, x_tr.shape[0], cfg.batch):
                    bx, by = x_tr[idx], y_tr[idx]
                    t_logits = _infer(params, bx, spec, teacher_path)
                    params, vel, loss = _train_step(
                        params, vel, lr_student, bx, by, t_logits,
                        spec, spath, cfg.tau, cfg.lam, cfg.momentum, True,
                    )
                    losses.append(float(loss))
                history.append((stage, "student", spath.name, epoch, float(np.mean(losses))))
                if progress:
                    print(f"[stage {stage}] student {spath.name} "
                          f"epoch {epoch}: loss {np.mean(losses):.4f}")
        alpha *= cfg.lr_stage_decay  # α ← α/10 in Alg. 2; softened for short runs

    # Final polish: the last-added block+head saw the fewest updates, so the
    # full path gets one extra teacher-only cycle (keeps full >= subnets,
    # the ordering the paper reports).
    full = MorphPath(n_stages, 100)
    lr_full = _lr_tree(params, spec, n_stages, alpha, cfg.gamma, head_lr=cfg.lr)
    vel = jax.tree.map(jnp.zeros_like, params)
    for epoch in range(cfg.epochs_per_stage):
        losses = []
        for idx in _epoch_batches(rng, x_tr.shape[0], cfg.batch):
            bx, by = x_tr[idx], y_tr[idx]
            params, vel, loss = _train_step(
                params, vel, lr_full, bx, by,
                jnp.zeros((bx.shape[0], spec.num_classes), jnp.float32),
                spec, full, cfg.tau, cfg.lam, cfg.momentum, False,
            )
            losses.append(float(loss))
        history.append((n_stages + 1, "polish", full.name, epoch, float(np.mean(losses))))
        if progress:
            print(f"[polish] {full.name} epoch {epoch}: loss {np.mean(losses):.4f}")

    accs = {
        p.name: model_mod.accuracy(params, spec, p, jnp.asarray(dataset.x_test), dataset.y_test)
        for p in spec.paths
    }
    return TrainResult(params, accs, history)


def label_only_train(
    spec: ModelSpec,
    dataset: data_mod.Dataset,
    path: MorphPath,
    cfg: TrainConfig = TrainConfig(),
) -> float:
    """Ablation baseline: train one subnet with labels only (no KD, no
    cycles). Used by tests/benches to show DistillCycle's KD advantage."""
    rng = np.random.default_rng(cfg.seed + 1)
    params = model_mod.init_params(spec, cfg.seed + 1)
    vel = jax.tree.map(jnp.zeros_like, params)
    x_tr = jnp.asarray(dataset.x_train)
    y_tr = jnp.asarray(dataset.y_train)
    lr = _lr_tree(params, spec, 1, cfg.lr, cfg.gamma)
    total_epochs = cfg.epochs_per_stage * len(spec.filters)
    for _ in range(total_epochs):
        for idx in _epoch_batches(rng, x_tr.shape[0], cfg.batch):
            bx, by = x_tr[idx], y_tr[idx]
            params, vel, _ = _train_step(
                params, vel, lr, bx, by,
                jnp.zeros((bx.shape[0], spec.num_classes), jnp.float32),
                spec, path, cfg.tau, cfg.lam, cfg.momentum, False,
            )
    return model_mod.accuracy(
        params, spec, path, jnp.asarray(dataset.x_test), dataset.y_test
    )
