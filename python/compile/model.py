"""L2 JAX model: the morphable CNN NeuroMorph deploys.

The paper's modular networks are ``a-2a-3a(-4a...)`` convolutional
pipelines (Sec. V): each **Layer-Block** is conv3x3(SAME)+ReLU+maxpool2,
and every morph path — a (depth, width) pair — owns a dedicated output
head (GAP + FC), mirroring Fig. 9.

* **Depth-wise morphing** truncates the block chain after ``depth`` blocks
  (clock-gating the rest).
* **Width-wise morphing** keeps the depth but activates only the first
  ``width%`` filters of every conv (and the matching input-channel slice
  of the next conv) — the software twin of gating half the PE array.

``forward`` is pure and path-static, so ``aot.py`` lowers *one HLO program
per morph path*: the gated weights are physically absent from the
artifact, exactly like gated PEs never toggling. Training (DistillCycle)
uses the pure-jnp reference ops; AOT inference uses the Pallas kernels —
both are pytest-proven equivalent.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv2d as conv_pallas
from .kernels import fc as fc_pallas
from .kernels import pool as pool_pallas
from .kernels import ref


class MorphPath(NamedTuple):
    """One morphable execution path: first ``depth`` blocks at ``width_pct``."""

    depth: int
    width_pct: int  # 100 or 50

    @property
    def name(self) -> str:
        return f"d{self.depth}_w{self.width_pct}"


class ModelSpec(NamedTuple):
    """Architecture descriptor (the a-2a-3a... modular pipeline)."""

    name: str
    input_shape: tuple[int, int, int]  # H, W, C
    num_classes: int
    filters: tuple[int, ...]  # per Layer-Block conv filter counts
    kernel: int = 3

    @property
    def paths(self) -> list[MorphPath]:
        """All morph paths: every depth at full width + full-depth half width."""
        out = [MorphPath(d, 100) for d in range(1, len(self.filters) + 1)]
        out.append(MorphPath(len(self.filters), 50))
        return out

    @property
    def full_path(self) -> MorphPath:
        return MorphPath(len(self.filters), 100)


#: The paper's Table II small benchmarks (synthetic-data stand-ins).
SPECS = {
    "mnist": ModelSpec("mnist", (28, 28, 1), 10, (8, 16, 32)),
    "svhn": ModelSpec("svhn", (32, 32, 3), 10, (8, 16, 32, 64)),
    "cifar10": ModelSpec("cifar10", (32, 32, 3), 10, (8, 16, 32, 64, 64)),
}


def feature_shape(spec: ModelSpec, depth: int) -> tuple[int, int]:
    """(H, W) of the feature map after ``depth`` Layer-Blocks."""
    h, w = spec.input_shape[:2]
    for _ in range(depth):
        if min(h, w) >= 2:
            h, w = h // 2, w // 2
    return h, w


def _head_dim(spec: ModelSpec, path: MorphPath) -> int:
    """FC head input size: the flattened streamed feature map (Eq. 5) —
    the paper's FC_PE consumes the conv output element-wise, so the head
    sees H*W*C features, not a pooled vector."""
    h, w = feature_shape(spec, path.depth)
    return h * w * _width(spec.filters[path.depth - 1], path.width_pct)


def _width(f: int, pct: int) -> int:
    return max(1, (f * pct) // 100)


def init_params(spec: ModelSpec, seed: int = 0) -> dict:
    """He-init conv blocks + one FC head per morph path."""
    rng = np.random.default_rng(seed)
    k = spec.kernel
    params: dict = {"blocks": [], "heads": {}}
    cin = spec.input_shape[2]
    for f in spec.filters:
        fan_in = k * k * cin
        params["blocks"].append(
            {
                "w": jnp.asarray(
                    rng.standard_normal((k, k, cin, f)) * np.sqrt(2.0 / fan_in),
                    jnp.float32,
                ),
                "b": jnp.zeros((f,), jnp.float32),
            }
        )
        cin = f
    for path in spec.paths:
        dim = _head_dim(spec, path)
        params["heads"][path.name] = {
            "w": jnp.asarray(
                rng.standard_normal((dim, spec.num_classes)) * np.sqrt(1.0 / dim),
                jnp.float32,
            ),
            "b": jnp.zeros((spec.num_classes,), jnp.float32),
        }
    return params


def slice_block(block: dict, cin_w: int, cout_w: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Width-morph a conv block: keep the first cin_w/cout_w channels."""
    return block["w"][:, :, :cin_w, :cout_w], block["b"][:cout_w]


def forward(
    params: dict,
    x: jnp.ndarray,
    spec: ModelSpec,
    path: MorphPath,
    use_pallas: bool = False,
    qbits: int | None = None,
) -> jnp.ndarray:
    """Logits for one morph path. x: [N,H,W,C] -> [N,classes].

    ``use_pallas`` selects the L1 kernels (AOT/deploy path); the default
    pure-jnp ops are the training path. ``qbits`` emulates the intN
    datapath on the deploy path (NeuroForge-8 / NeuroForge-16 variants).
    """
    if path.name not in params["heads"]:
        raise KeyError(f"path {path.name} has no trained head")
    conv = conv_pallas.conv2d if use_pallas else ref.conv2d
    dense = fc_pallas.fc if use_pallas else ref.fc
    mpool = pool_pallas.maxpool2d if use_pallas else ref.maxpool2d

    cin_w = x.shape[3]
    h = x
    for i in range(path.depth):
        cout_w = _width(spec.filters[i], path.width_pct)
        w, b = slice_block(params["blocks"][i], cin_w, cout_w)
        if use_pallas:
            h = conv(h, w, b, stride=1, padding="SAME", relu=True, qbits=qbits)
        else:
            h = conv(h, w, b, stride=1, padding="SAME", relu=True)
        if min(h.shape[1], h.shape[2]) >= 2:
            h = mpool(h, 2)
        cin_w = cout_w

    feats = h.reshape(h.shape[0], -1)  # stream the feature map into FC_PE
    head = params["heads"][path.name]
    return dense(feats, head["w"], head["b"])


def predict_fn(spec: ModelSpec, params: dict, path: MorphPath, qbits: int | None = None):
    """Closure over trained params for AOT lowering (Pallas deploy path)."""

    def fn(x: jnp.ndarray) -> tuple[jnp.ndarray]:
        return (forward(params, x, spec, path, use_pallas=True, qbits=qbits),)

    return fn


def accuracy(
    params: dict,
    spec: ModelSpec,
    path: MorphPath,
    x: jnp.ndarray,
    y: jnp.ndarray,
    batch: int = 256,
) -> float:
    """Top-1 accuracy of one morph path (training-path ops)."""
    hits = 0
    for i in range(0, x.shape[0], batch):
        logits = forward(params, x[i : i + batch], spec, path)
        hits += int(jnp.sum(jnp.argmax(logits, axis=1) == y[i : i + batch]))
    return hits / x.shape[0]


def count_params(spec: ModelSpec, path: MorphPath) -> int:
    """Parameter count of one path (active weights only)."""
    k = spec.kernel
    cin = spec.input_shape[2]
    total = 0
    for i in range(path.depth):
        cout = _width(spec.filters[i], path.width_pct)
        total += k * k * cin * cout + cout
        cin = cout
    total += _head_dim(spec, path) * spec.num_classes + spec.num_classes
    return total


def count_macs(spec: ModelSpec, path: MorphPath) -> int:
    """MAC count of one path on its input resolution (conv + head)."""
    k = spec.kernel
    h, w, cin = spec.input_shape
    total = 0
    for i in range(path.depth):
        cout = _width(spec.filters[i], path.width_pct)
        total += h * w * k * k * cin * cout  # SAME conv
        if min(h, w) >= 2:
            h, w = h // 2, w // 2
        cin = cout
    total += h * w * cin * spec.num_classes
    return total
