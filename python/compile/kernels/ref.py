"""Pure-jnp reference oracle for every L1 Pallas kernel.

These are the ground-truth semantics the Pallas kernels in this package are
tested against (pytest + hypothesis in ``python/tests``). They mirror the
FPGA Processing Elements of the paper:

* ``conv2d``      — the ``C_PE`` (line buffer + K^2-MAC core, Sec. III-A.1)
* ``maxpool2d`` / ``avgpool2d`` — the ``PU_PE`` (Sec. III-A.2)
* ``fc``          — the ``FC_PE`` (Eq. 5/6)
* ``quantize`` / ``dequantize`` — the int8/int16 fixed-point datapath
  (``FP_rep`` of Eq. 11)

Layout convention: NHWC for activations, HWIO for conv weights — the same
layout the streaming pipeline uses (one pixel per clock, channel-parallel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_same(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """SAME-pad the spatial dims of an NHWC tensor for kernel size ``k``.

    Matches the hardware padding stage (T_pad in Eq. 4): zeros are injected
    around the frame before the line buffer assembles windows.
    """
    h, w = x.shape[1], x.shape[2]
    out_h = -(-h // stride)
    out_w = -(-w // stride)
    pad_h = max((out_h - 1) * stride + k - h, 0)
    pad_w = max((out_w - 1) * stride + k - w, 0)
    return jnp.pad(
        x,
        (
            (0, 0),
            (pad_h // 2, pad_h - pad_h // 2),
            (pad_w // 2, pad_w - pad_w // 2),
            (0, 0),
        ),
    )


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
) -> jnp.ndarray:
    """Reference 2-D convolution. x: [N,H,W,Cin], w: [K,K,Cin,Cout]."""
    if padding not in ("SAME", "VALID"):
        raise ValueError(f"unsupported padding {padding!r}")
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b.astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def _pool_patches(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """Extract [N, Ho, Wo, k*k, C] pooling windows (VALID padding)."""
    n, h, w, c = x.shape
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    rows = []
    for di in range(k):
        for dj in range(k):
            rows.append(
                jax.lax.slice(
                    x,
                    (0, di, dj, 0),
                    (n, di + (ho - 1) * stride + 1, dj + (wo - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    return jnp.stack(rows, axis=3)


def maxpool2d(x: jnp.ndarray, k: int = 2, stride: int | None = None) -> jnp.ndarray:
    """Reference max pooling (VALID), the comparator-tree PU_PE."""
    stride = stride or k
    return jnp.max(_pool_patches(x, k, stride), axis=3)


def avgpool2d(x: jnp.ndarray, k: int = 2, stride: int | None = None) -> jnp.ndarray:
    """Reference average pooling (VALID): C_PE with fixed 1/k^2 coefficients."""
    stride = stride or k
    return jnp.mean(_pool_patches(x, k, stride).astype(jnp.float32), axis=3)


def fc(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    relu: bool = False,
) -> jnp.ndarray:
    """Reference fully connected layer. x: [N,F], w: [F,O] (Eq. 5)."""
    out = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    """[N,H,W,C] -> [N,C] global average pooling (head input)."""
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2))


# ---------------------------------------------------------------------------
# Fixed-point datapath (FP_rep in Eq. 11): symmetric affine quantization.
# ---------------------------------------------------------------------------

_QINFO = {8: (-128, 127), 16: (-32768, 32767)}


def quant_scale(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Per-tensor symmetric scale so that max|x| maps to the int max."""
    _, qmax = _QINFO[bits]
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-8)
    return amax / qmax


def quantize(x: jnp.ndarray, scale: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Round-to-nearest fixed-point quantization, clipped to the int range."""
    qmin, qmax = _QINFO[bits]
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), qmin, qmax)
    return q.astype(jnp.int32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Quantize-dequantize round trip used to emulate the intN datapath."""
    s = quant_scale(x, bits)
    return dequantize(quantize(x, s, bits), s)
