"""L1 Pallas kernels: max / average pooling (the paper's PU_PE).

The PU_PE reuses the C_PE line-buffer controller and swaps the MAC core
for a K^2-comparator tree (max) or fixed 1/K^2 coefficients (avg),
Sec. III-A.2. The TPU mapping mirrors ``conv2d.py``: the frame is staged
in VMEM, a grid walks output-row tiles, and the comparator tree becomes a
max/mean reduction over the K^2 tap axis. Pooling uses VALID padding and
``stride == k`` by default, matching the streaming pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _pool_kernel(
    x_ref,
    o_ref,
    *,
    k: int,
    stride: int,
    tile_h: int,
    w_out: int,
    mode: str,
):
    i = pl.program_id(1)
    x = x_ref[0]  # [Hp, Wp, C]
    in_tile_h = (tile_h - 1) * stride + k
    slab = jax.lax.dynamic_slice(
        x, (i * tile_h * stride, 0, 0), (in_tile_h, x.shape[1], x.shape[2])
    )
    row_span = (tile_h - 1) * stride + 1
    col_span = (w_out - 1) * stride + 1
    taps = []
    for di in range(k):
        for dj in range(k):
            taps.append(slab[di : di + row_span : stride, dj : dj + col_span : stride, :])
    patches = jnp.stack(taps, axis=2)  # [tile_h, w_out, K*K, C]
    if mode == "max":
        o_ref[0] = jnp.max(patches, axis=2)
    else:
        o_ref[0] = jnp.mean(patches, axis=2)


def _pool(
    x: jnp.ndarray, k: int, stride: int, tile_h: int, mode: str
) -> jnp.ndarray:
    n, h, width, c = x.shape
    if h < k or width < k:
        raise ValueError(f"frame {h}x{width} smaller than pool window {k}")
    h_out = (h - k) // stride + 1
    w_out = (width - k) // stride + 1
    tile_h = min(tile_h, h_out)
    grid_h = common.ceil_div(h_out, tile_h)
    x = x.astype(jnp.float32)

    need_rows = (grid_h * tile_h - 1) * stride + k
    if need_rows > h:
        # min-identity padding keeps max-pool semantics on the crop region
        pad_val = -jnp.inf if mode == "max" else 0.0
        x = jnp.pad(
            x,
            ((0, 0), (0, need_rows - h), (0, 0), (0, 0)),
            constant_values=pad_val,
        )

    kernel = functools.partial(
        _pool_kernel, k=k, stride=stride, tile_h=tile_h, w_out=w_out, mode=mode
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, grid_h),
        in_specs=[
            pl.BlockSpec((1, x.shape[1], x.shape[2], c), lambda bn, bi: (bn, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, w_out, c), lambda bn, bi: (bn, bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, grid_h * tile_h, w_out, c), jnp.float32),
        interpret=True,
    )(x)
    return out[:, :h_out]


@functools.partial(jax.jit, static_argnames=("k", "stride", "tile_h"))
def maxpool2d(
    x: jnp.ndarray,
    k: int = 2,
    stride: int | None = None,
    tile_h: int = common.DEFAULT_TILE_H,
) -> jnp.ndarray:
    """Pallas max pooling, VALID padding. x: [N,H,W,C]."""
    return _pool(x, k, stride or k, tile_h, "max")


@functools.partial(jax.jit, static_argnames=("k", "stride", "tile_h"))
def avgpool2d(
    x: jnp.ndarray,
    k: int = 2,
    stride: int | None = None,
    tile_h: int = common.DEFAULT_TILE_H,
) -> jnp.ndarray:
    """Pallas average pooling, VALID padding. x: [N,H,W,C]."""
    return _pool(x, k, stride or k, tile_h, "avg")


@jax.jit
def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    """[N,H,W,C] -> [N,C]; the head input reduction, one program per batch."""

    def kernel(x_ref, o_ref):
        o_ref[0] = jnp.mean(x_ref[0], axis=(0, 1))

    n, h, w, c = x.shape
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda bn: (bn, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c), lambda bn: (bn, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
