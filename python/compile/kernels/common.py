"""Shared helpers for the L1 Pallas kernels.

Hardware-adaptation note (DESIGN.md §4): the paper's C_PE keeps K-1 input
rows resident in BRAM line buffers so each pixel is read from DRAM exactly
once. On TPU the analogous resource is VMEM: for the streaming CNN frames
the paper targets (28x28..32x32, <=64ch) the *whole* padded frame fits in
VMEM with room to spare, so each kernel stages the frame once and walks it
with a grid over output-row tiles — the grid is the TPU realization of the
paper's one-row-per-beat streaming schedule, and the im2col x matmul inner
step maps the K^2 DSP-MAC array onto the MXU systolic array.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path (see
/opt/xla-example/README.md). Real-TPU performance is estimated analytically
in EXPERIMENTS.md §Perf from the VMEM footprint + MXU shapes chosen here.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Fixed-point ranges for the intN datapath (FP_rep of Eq. 11).
QINFO = {8: (-128.0, 127.0), 16: (-32768.0, 32767.0)}

#: Default output-row tile height for the conv/pool grids. 8 rows x 32 px x
#: 64 ch of f32 is 64 KiB — small against the ~16 MiB of VMEM, leaving the
#: grid pipeline room to double-buffer the next tile.
DEFAULT_TILE_H = 8


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def same_pads(size: int, k: int, stride: int) -> tuple[int, int]:
    """(lo, hi) zero padding for SAME semantics on one spatial dim."""
    out = ceil_div(size, stride)
    pad = max((out - 1) * stride + k - size, 0)
    return pad // 2, pad - pad // 2


def out_size(size: int, k: int, stride: int, padding: str) -> int:
    if padding == "SAME":
        return ceil_div(size, stride)
    return (size - k) // stride + 1


def fake_quant_static(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize-dequantize with a precomputed scale (kernel epilogue).

    Emulates the intN DSP datapath inside the MAC core: values are rounded
    to the fixed-point grid and clipped to the representable range before
    entering the multiplier array.
    """
    qmin, qmax = QINFO[bits]
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    return q * scale
