"""L1 Pallas kernel: channel-parallel fully connected layer (FC_PE).

The paper's FC_PE streams inputs through one MAC per output head (Eq. 5)
and breaks the serialization bottleneck by processing input channels with
parallel FC-Accumulation blocks (Eq. 6). On TPU the per-head MAC array
becomes a matmul tile on the MXU; the parallelism coefficient
``P = Ch^D / FC_PE`` becomes the output-column grid: each program computes
one ``tile_o``-wide slice of heads, so ``grid = ceil(O / tile_o)`` plays
the role of the FC_PE allocation count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _fc_kernel(x_ref, w_ref, b_ref, s_ref, o_ref, *, relu: bool, qbits: int | None):
    x = x_ref[...]  # [N, F]
    w = w_ref[...]  # [F, tile_o]
    if qbits is not None:
        x = common.fake_quant_static(x, s_ref[0], qbits)
        w = common.fake_quant_static(w, s_ref[1], qbits)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32) + b_ref[...]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("relu", "tile_o", "qbits"))
def fc(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    relu: bool = False,
    tile_o: int = 128,
    qbits: int | None = None,
) -> jnp.ndarray:
    """Pallas fully connected layer. x: [N,F], w: [F,O] -> [N,O]."""
    n, f = x.shape
    if w.shape[0] != f:
        raise ValueError(f"weight shape {w.shape} incompatible with input {x.shape}")
    o = w.shape[1]
    if b is None:
        b = jnp.zeros((o,), jnp.float32)
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)

    tile_o = min(tile_o, o)
    grid_o = common.ceil_div(o, tile_o)
    o_pad = grid_o * tile_o
    if o_pad != o:
        w = jnp.pad(w, ((0, 0), (0, o_pad - o)))
        b = jnp.pad(b, (0, o_pad - o))

    if qbits is not None:
        qmax = common.QINFO[qbits][1]
        scales = jnp.stack(
            [
                jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax,
                jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / qmax,
            ]
        )
    else:
        scales = jnp.ones((2,), jnp.float32)

    kernel = functools.partial(_fc_kernel, relu=relu, qbits=qbits)
    out = pl.pallas_call(
        kernel,
        grid=(grid_o,),
        in_specs=[
            pl.BlockSpec((n, f), lambda bo: (0, 0)),
            pl.BlockSpec((f, tile_o), lambda bo: (0, bo)),
            pl.BlockSpec((tile_o,), lambda bo: (bo,)),
            pl.BlockSpec((2,), lambda bo: (0,)),
        ],
        out_specs=pl.BlockSpec((n, tile_o), lambda bo: (0, bo)),
        out_shape=jax.ShapeDtypeStruct((n, o_pad), jnp.float32),
        interpret=True,
    )(x, w, b, scales)
    return out[:, :o]
