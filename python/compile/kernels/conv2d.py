"""L1 Pallas kernel: streaming 2-D convolution (the paper's C_PE).

The FPGA C_PE is a two-stage pipeline — a Line Buffer Controller that
assembles K x K windows from streamed rows, and a MAC core with K^2
multipliers + an adder tree (Eqs. 1-3). The TPU mapping (DESIGN.md §4):

* line buffer  -> the padded frame staged once into VMEM;
* row streaming -> a grid over output-row tiles (one program per tile);
* K^2 DSP MACs + adder tree -> an im2col gather per tile feeding one
  (tile_h * W_out, K^2 * C_in) x (K^2 * C_in, C_out) MXU matmul;
* intN datapath -> optional fake-quant of activations/weights in-kernel.

``interpret=True`` always: the CPU PJRT backend cannot run Mosaic
custom-calls; numerics are validated against ``ref.conv2d`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _conv_kernel(
    x_ref,
    w_ref,
    b_ref,
    s_ref,
    o_ref,
    *,
    k: int,
    stride: int,
    tile_h: int,
    w_out: int,
    relu: bool,
    qbits: int | None,
):
    """One grid step: produce ``tile_h`` output rows for one batch element."""
    i = pl.program_id(1)
    x = x_ref[0]  # [Hp, Wp, Cin] — the VMEM-resident "line buffer"
    w = w_ref[...]  # [K, K, Cin, Cout]
    if qbits is not None:
        # intN MAC datapath: operands snap to the fixed-point grid before
        # entering the multiplier array (DSP slices in the paper). The
        # per-tensor scales ride in as a tiny SMEM-style operand.
        x = common.fake_quant_static(x, s_ref[0], qbits)
        w = common.fake_quant_static(w, s_ref[1], qbits)

    in_tile_h = (tile_h - 1) * stride + k
    slab = jax.lax.dynamic_slice(
        x, (i * tile_h * stride, 0, 0), (in_tile_h, x.shape[1], x.shape[2])
    )

    # Window assembly (the Line Buffer Controller tap stage): K^2 strided
    # views of the slab, stacked to an im2col tile.
    row_span = (tile_h - 1) * stride + 1
    col_span = (w_out - 1) * stride + 1
    taps = []
    for di in range(k):
        for dj in range(k):
            taps.append(slab[di : di + row_span : stride, dj : dj + col_span : stride, :])
    patches = jnp.stack(taps, axis=2)  # [tile_h, w_out, K*K, Cin]
    cin = x.shape[2]
    lhs = patches.reshape(tile_h * w_out, k * k * cin)
    rhs = w.reshape(k * k * cin, -1)

    # The MXU matmul standing in for the K^2-DSP MAC array + adder tree.
    acc = jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
    acc = acc.reshape(tile_h, w_out, -1) + b_ref[...]
    if relu:
        acc = jnp.maximum(acc, 0.0)  # comparator ReLU stage (T_ReLU)
    o_ref[0] = acc


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "relu", "tile_h", "qbits"),
)
def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    tile_h: int = common.DEFAULT_TILE_H,
    qbits: int | None = None,
) -> jnp.ndarray:
    """Pallas streaming convolution. x: [N,H,W,Cin], w: [K,K,Cin,Cout]."""
    n, h, width, cin = x.shape
    k = w.shape[0]
    if w.shape[1] != k or w.shape[2] != cin:
        raise ValueError(f"weight shape {w.shape} incompatible with input {x.shape}")
    cout = w.shape[3]
    if b is None:
        b = jnp.zeros((cout,), jnp.float32)

    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    if padding == "SAME":
        ph = common.same_pads(h, k, stride)
        pw = common.same_pads(width, k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    elif padding != "VALID":
        raise ValueError(f"unsupported padding {padding!r}")

    h_out = common.out_size(h, k, stride, padding)
    w_out = common.out_size(width, k, stride, padding)
    tile_h = min(tile_h, h_out)
    grid_h = common.ceil_div(h_out, tile_h)

    # Over-pad rows so the last tile's dynamic_slice stays in bounds; the
    # surplus output rows are cropped after the pallas_call.
    need_rows = (grid_h * tile_h - 1) * stride + k
    if need_rows > x.shape[1]:
        x = jnp.pad(x, ((0, 0), (0, need_rows - x.shape[1]), (0, 0), (0, 0)))

    # Per-tensor scales for the intN datapath (ignored when qbits is None).
    if qbits is not None:
        qmax = common.QINFO[qbits][1]
        scales = jnp.stack(
            [
                jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax,
                jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / qmax,
            ]
        )
    else:
        scales = jnp.ones((2,), jnp.float32)

    kernel = functools.partial(
        _conv_kernel,
        k=k,
        stride=stride,
        tile_h=tile_h,
        w_out=w_out,
        relu=relu,
        qbits=qbits,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, grid_h),
        in_specs=[
            pl.BlockSpec(
                (1, x.shape[1], x.shape[2], cin), lambda bn, bi: (bn, 0, 0, 0)
            ),
            pl.BlockSpec((k, k, cin, cout), lambda bn, bi: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda bn, bi: (0,)),
            pl.BlockSpec((2,), lambda bn, bi: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (1, tile_h, w_out, cout), lambda bn, bi: (bn, bi, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n, grid_h * tile_h, w_out, cout), jnp.float32),
        interpret=True,
    )(x, w, b, scales)
    return out[:, :h_out]
