"""AOT compile path: DistillCycle-train, then lower every morph path to HLO.

This is the *only* place Python touches the pipeline — ``make artifacts``
runs it once; the Rust coordinator then loads ``artifacts/*.hlo.txt`` via
PJRT and never imports Python again (DESIGN.md §3).

Per model we emit one HLO **text** program per (morph path, batch size):
the morph path's gated weights are baked out of the artifact entirely —
the software analogue of clock-gated PEs. Interchange is HLO text, not
serialized protos: jax>=0.5 emits 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

``manifest.json`` records everything the Rust side needs: shapes, paths,
artifact files, DistillCycle accuracies, per-path parameter/MAC counts.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    ``print_large_constants=True`` is load-bearing: the default HLO
    printer elides big literals as ``constant({...})``, and the trained
    weights baked into each morph path ARE big literals — without it the
    Rust side would compile a model full of zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


# ---------------------------------------------------------------------------
# Parameter cache (training is minutes; lowering is seconds — cache the
# former keyed on the training configuration).
# ---------------------------------------------------------------------------


def _flatten_params(params: dict) -> dict:
    flat = {}
    for i, blk in enumerate(params["blocks"]):
        flat[f"block{i}/w"] = np.asarray(blk["w"])
        flat[f"block{i}/b"] = np.asarray(blk["b"])
    for name, head in params["heads"].items():
        flat[f"head/{name}/w"] = np.asarray(head["w"])
        flat[f"head/{name}/b"] = np.asarray(head["b"])
    return flat


def _unflatten_params(flat: dict) -> dict:
    params: dict = {"blocks": [], "heads": {}}
    n_blocks = len({k for k in flat if k.startswith("block") and k.endswith("/w")})
    for i in range(n_blocks):
        params["blocks"].append(
            {"w": jnp.asarray(flat[f"block{i}/w"]), "b": jnp.asarray(flat[f"block{i}/b"])}
        )
    heads = sorted({k.split("/")[1] for k in flat if k.startswith("head/")})
    for name in heads:
        params["heads"][name] = {
            "w": jnp.asarray(flat[f"head/{name}/w"]),
            "b": jnp.asarray(flat[f"head/{name}/b"]),
        }
    return params


def _train_key(model_name: str, cfg: train_mod.TrainConfig, n_train: int) -> str:
    blob = json.dumps([model_name, list(cfg), n_train], sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def train_or_load(
    model_name: str,
    out_dir: str,
    cfg: train_mod.TrainConfig,
    n_train: int,
    verbose: bool = True,
) -> tuple[dict, dict]:
    """Return (params, accuracies), training only on cache miss."""
    spec = model_mod.SPECS[model_name]
    key = _train_key(model_name, cfg, n_train)
    cache = os.path.join(out_dir, f"params_{model_name}_{key}.npz")
    meta = os.path.join(out_dir, f"params_{model_name}_{key}.json")
    if os.path.exists(cache) and os.path.exists(meta):
        with open(meta) as f:
            accs = json.load(f)["accuracies"]
        params = _unflatten_params(dict(np.load(cache)))
        if verbose:
            print(f"[aot] {model_name}: loaded cached params ({key})")
        return params, accs

    if verbose:
        print(f"[aot] {model_name}: DistillCycle training ({n_train} samples)...")
    t0 = time.time()
    dataset = data_mod.make_dataset(model_name, n_train=n_train, n_test=512, seed=cfg.seed)
    result = train_mod.distillcycle_train(spec, dataset, cfg)
    if verbose:
        accs_s = {k: round(v, 4) for k, v in result.accuracies.items()}
        print(f"[aot] {model_name}: trained in {time.time() - t0:.1f}s, acc {accs_s}")
    np.savez(cache, **_flatten_params(result.params))
    with open(meta, "w") as f:
        json.dump({"accuracies": result.accuracies, "config": list(cfg)}, f)
    return result.params, result.accuracies


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def lower_path(
    spec: model_mod.ModelSpec,
    params: dict,
    path: model_mod.MorphPath,
    batch: int,
    qbits: int | None = None,
) -> str:
    """Lower one morph path's Pallas inference fn to HLO text."""
    fn = model_mod.predict_fn(spec, params, path, qbits=qbits)
    h, w, c = spec.input_shape
    x_spec = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec)
    return to_hlo_text(lowered)


def build_model(
    model_name: str,
    out_dir: str,
    batches: list[int],
    cfg: train_mod.TrainConfig,
    n_train: int,
    emit_quant_full: bool = True,
    verbose: bool = True,
) -> dict:
    """Train (or load) one model and emit all its path artifacts."""
    spec = model_mod.SPECS[model_name]
    params, accs = train_or_load(model_name, out_dir, cfg, n_train, verbose)

    paths_meta = []
    for path in spec.paths:
        artifacts = {}
        for b in batches:
            fname = f"{model_name}_{path.name}_b{b}.hlo.txt"
            text = lower_path(spec, params, path, b)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            artifacts[str(b)] = fname
            if verbose:
                print(f"[aot]   wrote {fname} ({len(text)} chars)")
        paths_meta.append(
            {
                "name": path.name,
                "depth": path.depth,
                "width_pct": path.width_pct,
                "accuracy": accs[path.name],
                "params": model_mod.count_params(spec, path),
                "macs": model_mod.count_macs(spec, path),
                "artifacts": artifacts,
            }
        )

    quant_artifacts = {}
    if emit_quant_full:
        # One int8-datapath artifact of the full path: proves the quantized
        # deploy path (NeuroForge-8) composes end-to-end through PJRT.
        fname = f"{model_name}_{spec.full_path.name}_q8_b1.hlo.txt"
        text = lower_path(spec, params, spec.full_path, 1, qbits=8)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        quant_artifacts["8"] = fname
        if verbose:
            print(f"[aot]   wrote {fname} ({len(text)} chars)")

    # Reference logits on a fixed probe batch so the Rust integration test
    # can verify numerics end-to-end without Python at runtime.
    probe_ds = data_mod.make_dataset(model_name, n_train=8, n_test=8, seed=123)
    probe_x = probe_ds.x_test[: max(batches)]
    probe = {
        "x": np.asarray(probe_x, np.float32).ravel().tolist(),
        "shape": list(probe_x.shape),
        "logits": {},
    }
    for path in spec.paths:
        logits = model_mod.forward(
            params, jnp.asarray(probe_x), spec, path, use_pallas=True
        )
        probe["logits"][path.name] = np.asarray(logits, np.float32).ravel().tolist()

    return {
        "input_shape": list(spec.input_shape),
        "num_classes": spec.num_classes,
        "filters": list(spec.filters),
        "kernel": spec.kernel,
        "batches": batches,
        "paths": paths_meta,
        "quant_full": quant_artifacts,
        "probe": probe,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="mnist", help="comma list: mnist,svhn,cifar10")
    ap.add_argument("--batches", default="1,8", help="comma list of batch sizes")
    ap.add_argument("--train-size", type=int, default=2048)
    ap.add_argument("--epochs-per-stage", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    batches = [int(b) for b in args.batches.split(",")]
    cfg = train_mod.TrainConfig(
        epochs_per_stage=args.epochs_per_stage, seed=args.seed
    )

    manifest = {"version": 1, "generated_unix": int(time.time()), "models": {}}
    for name in args.models.split(","):
        manifest["models"][name] = build_model(
            name, args.out_dir, batches, cfg, args.train_size, verbose=not args.quiet
        )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
